"""Benchmarks for the paper's claims (it is a theory paper — no tables —
so each 'table' is a complexity claim made measurable):

  T1  queries + wall-time per node evaluation vs #prev-leaves M:
      exact Alg 2 is O(M²τ) per node (Thm 2.4), sketched Alg 3 is O(Mτ)
      (Thm 3.1).
  T2  sketched-SSR relative error vs k  (Thm 3.4: ε ≈ 1/√(kδ)).
  T3  SumProd engine: grouped-query wall time vs |rows| and vs the
      materialized-join size it avoids.
  T4  beyond-paper: frequency-domain ⊗ (O(k)) vs the paper's
      coefficient/FFT ⊗ (O(k log k)) inside the same training run.
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from _common import emit
from repro.core import (
    Arithmetic, BoostConfig, Booster, Channels, PolyFreq, SumProd,
    TableHashes, materialize_join, predict_rows, sketch_factors,
)
from repro.relational.generators import star_schema


def _timeit(fn, n=3):
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6  # µs


def t1_exact_vs_sketch_scaling(depths=(1, 2, 3), n_fact=400):
    rows: List[dict] = []
    sch = star_schema(seed=3, n_fact=n_fact, n_dim=32)
    for depth in depths:
        L = 2 ** depth
        for mode in ("exact", "sketch"):
            cfg = BoostConfig(n_trees=2, depth=depth, mode=mode, sketch_k=128)
            b = Booster(sch, cfg)
            t0 = time.perf_counter()
            trees, trace = b.fit()
            dt = time.perf_counter() - t0
            rows.append({
                "bench": "T1", "mode": mode, "L": L, "M": L,
                "queries": trace.queries, "wall_s": round(dt, 2),
            })
    return rows


def t2_error_vs_k(ks=(64, 128, 256, 512, 1024), n_fact=400):
    rows = []
    sch = star_schema(seed=5, n_fact=n_fact, n_dim=32)
    exact_cfg = BoostConfig(n_trees=2, depth=2, mode="exact")
    _, tre = Booster(sch, exact_cfg).fit()
    for k in ks:
        cfg = BoostConfig(n_trees=2, depth=2, mode="sketch", sketch_k=k, seed=11)
        _, trs = Booster(sch, cfg).fit()
        errs = []
        for e, s in zip(tre.node_ssr, trs.node_ssr):
            for tbl in e:
                if tbl == "fact":
                    continue
                ee, ss = np.asarray(e[tbl]), np.asarray(s[tbl])
                m = ee > 1.0
                if m.any():
                    errs.append((np.abs(ss - ee) / ee)[m])
        err = float(np.concatenate(errs).mean())
        rows.append({"bench": "T2", "k": k, "ssr_rel_err": round(err, 4),
                     "inv_sqrt_k": round(1 / np.sqrt(k), 4)})
    return rows


def t3_engine_throughput(sizes=(1000, 4000, 16000)):
    rows = []
    for n in sizes:
        sch = star_schema(seed=7, n_fact=n, n_dim=max(16, n // 16))
        sp = SumProd(sch)
        c3 = Channels(3)
        f = sp.ones_factors(c3)
        lbl = sch.labels
        f[sch.label_table] = jnp.stack([jnp.ones_like(lbl), lbl, lbl ** 2], -1)
        us = _timeit(jax.jit(lambda: sp(c3, f, group_by="dim0")))
        J = materialize_join(sch)
        rows.append({
            "bench": "T3", "rows": n,
            "grouped_query_us": round(us, 1),
            "rows_per_s": int(n / (us * 1e-6)),
            "join_rows_avoided": int(J[sch.label_column].shape[0]),
        })
    return rows


def t4_freq_vs_coeff(n_fact=400, k=256):
    rows = []
    sch = star_schema(seed=9, n_fact=n_fact, n_dim=32)
    for domain in ("freq", "coeff"):
        cfg = BoostConfig(n_trees=2, depth=2, mode="sketch", sketch_k=k,
                          sketch_domain=domain)
        b = Booster(sch, cfg)
        t0 = time.perf_counter()
        trees, _ = b.fit()
        dt = time.perf_counter() - t0
        # also time one raw sketched grouped query
        sem = b.sem
        fac = sketch_factors(sch, sem, b.hashes, sch.label_table, sch.labels)
        us = _timeit(jax.jit(lambda: b.sp(sem, fac, group_by="dim0")))
        rows.append({"bench": "T4", "domain": domain, "k": k,
                     "fit_wall_s": round(dt, 2),
                     "grouped_sketch_query_us": round(us, 1)})
    return rows


def run_all(fast: bool = True):
    rows = []
    rows += t1_exact_vs_sketch_scaling(depths=(1, 2) if fast else (1, 2, 3))
    rows += t2_error_vs_k(ks=(64, 256, 1024) if fast else (64, 128, 256, 512, 1024))
    rows += t3_engine_throughput(sizes=(1000, 4000) if fast else (1000, 4000, 16000))
    rows += t4_freq_vs_coeff()
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (fast path)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    fast = not args.full
    rows = run_all(fast=fast)
    for r in rows:
        print(r)
    # pinned metrics are counted work / convergence ratios, not wall-clock:
    # T1's query counts are analytic (Thm 2.4 vs 3.1) and the deepest depth
    # has the widest exact/sketch gap; T2's error at the largest k is the
    # sketch-accuracy floor
    t1 = [r for r in rows if r["bench"] == "T1"]
    deepest = max(r["L"] for r in t1)
    q = {r["mode"]: r["queries"] for r in t1 if r["L"] == deepest}
    t2 = [r for r in rows if r["bench"] == "T2"]
    best_k = max(t2, key=lambda r: r["k"])
    emit("paper", rows, {
        "t1_query_ratio_deepest": round(q["exact"] / max(q["sketch"], 1), 2),
        "t2_rel_err_at_max_k": best_k["ssr_rel_err"],
    }, config={"fast": fast})
    return rows


if __name__ == "__main__":
    main()
