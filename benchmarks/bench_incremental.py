"""Incremental maintenance benchmark: delta refresh vs full recompute.

  I1  Path-locality: a single-table delta re-emits segment-⊕ messages
      only on the changed table's root path.  Sweeping the number of
      dimension tables D on a star schema, a one-dim delta costs 1 edge
      while a full inside-out recompute costs D — the QueryCounter edge
      ratio grows linearly with schema width (asymptotic claim).  Chain
      and snowflake shapes pin the depth>1 path cases (1 of τ−1 and
      2 of 2D edges).  Maintained scores are audited against a fresh
      ``compile_ensemble`` over the effective live tables — exact match
      required (f32).
  I2  Update latency vs delta size: wall time of maintain-and-score
      after k-row deltas against the full recompute on the same state.

    PYTHONPATH=src python benchmarks/bench_incremental.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from _common import emit
from repro.core import BoostConfig, Booster, QueryCounter
from repro.incremental import MaintainedScorer, TableDelta
from repro.relational.generators import chain_schema, snowflake_schema, star_schema
from repro.serving import compile_ensemble


def _fit(schema, n_trees=3, depth=2):
    cfg = BoostConfig(n_trees=n_trees, depth=depth, mode="sketch", ssr_mode="off")
    return Booster(schema, cfg).fit()[0]


def _update_delta(ms, table, k, rng):
    """A k-row feature update against ``table`` (non-key columns)."""
    live = ms.live_rows(table)
    slots = rng.choice(live, size=min(k, len(live)), replace=False)
    t = ms.schema.table(table)
    keyish = {c for e in ms.edges.values() if table in e.tables for c in e.key_cols}
    cols = {
        c: rng.standard_normal(len(slots)).astype(np.float32)
        for c in t.feature_columns if c not in keyish
    }
    return TableDelta(table=table, updates=(np.sort(slots), cols))


def _audit(ms, group):
    tot_o, cnt_o = ms.recompute_oracle(group)
    tot_m, cnt_m = ms.grouped_cached(group)
    return (np.array_equal(np.asarray(tot_m), np.asarray(tot_o))
            and np.array_equal(np.asarray(cnt_m), np.asarray(cnt_o)))


def _measure(ms, group, make_delta):
    """(incremental ms, full-recompute ms, edges_inc, edges_full).

    ``make_delta`` must return same-shaped deltas; the first one warms
    the message cache and the delta-shaped op traces, the second is
    timed (apply + path-restricted refresh) against a warmed full
    recompute over the same state."""
    c = ms.counter
    ms.grouped_cached(group)                       # prime message cache
    ms.apply(make_delta())                         # warm delta-shaped ops
    ms.grouped_cached(group)
    ms.score_full(group)                           # warm the full pass
    e0 = c.edges
    t0 = time.perf_counter()
    ms.apply(make_delta())
    ms.grouped_cached(group)
    dt_inc = (time.perf_counter() - t0) * 1e3
    edges_inc = c.edges - e0
    e0 = c.edges
    t0 = time.perf_counter()
    ms.score_full(group)
    dt_full = (time.perf_counter() - t0) * 1e3
    edges_full = c.edges - e0
    return dt_inc, dt_full, edges_inc, edges_full


def i1_path_locality(smoke: bool):
    rows = []
    rng = np.random.default_rng(0)
    dims = [2, 4] if smoke else [2, 4, 8]
    n_fact = 400 if smoke else 2000
    for d in dims:
        sch = star_schema(seed=1, n_fact=n_fact, n_dim=32, n_dim_tables=d)
        ms = MaintainedScorer(compile_ensemble(sch, _fit(sch)),
                              counter=QueryCounter())
        dt_i, dt_f, e_i, e_f = _measure(
            ms, "fact", lambda: _update_delta(ms, "dim0", 4, rng))
        assert _audit(ms, "fact"), "maintained scores drifted from oracle"
        assert e_i < e_f, "refresh must re-emit fewer edges than a full pass"
        rows.append({
            "bench": "I1", "schema": f"star(D={d})", "delta": "dim0 ×4 rows",
            "edges_incremental": e_i, "edges_full": e_f,
            "edge_ratio": round(e_f / e_i, 1),
            "ms_incremental": round(dt_i, 1), "ms_full": round(dt_f, 1),
            "oracle_exact": True,
        })
    # deeper shapes: the path is still local but longer than one edge
    sch = chain_schema(seed=2, n_rows=200 if smoke else 600, n_tables=4)
    ms = MaintainedScorer(compile_ensemble(sch, _fit(sch)), counter=QueryCounter())
    dt_i, dt_f, e_i, e_f = _measure(ms, "t0",
                                    lambda: _update_delta(ms, "t1", 4, rng))
    assert _audit(ms, "t0") and e_i < e_f
    rows.append({
        "bench": "I1", "schema": "chain(τ=4)", "delta": "t1 ×4 rows",
        "edges_incremental": e_i, "edges_full": e_f,
        "edge_ratio": round(e_f / e_i, 1),
        "ms_incremental": round(dt_i, 1), "ms_full": round(dt_f, 1),
        "oracle_exact": True,
    })
    sch = snowflake_schema(seed=3, n_fact=200 if smoke else 1000,
                           n_dim=16, n_sub=4, n_dim_tables=3)
    ms = MaintainedScorer(compile_ensemble(sch, _fit(sch)), counter=QueryCounter())
    dt_i, dt_f, e_i, e_f = _measure(ms, "fact",
                                    lambda: _update_delta(ms, "sub0", 2, rng))
    assert _audit(ms, "fact") and e_i < e_f
    rows.append({
        "bench": "I1", "schema": "snowflake(D=3)", "delta": "sub0 ×2 rows",
        "edges_incremental": e_i, "edges_full": e_f,
        "edge_ratio": round(e_f / e_i, 1),
        "ms_incremental": round(dt_i, 1), "ms_full": round(dt_f, 1),
        "oracle_exact": True,
    })
    return rows


def i2_delta_size_sweep(smoke: bool):
    rng = np.random.default_rng(7)
    n_fact = 500 if smoke else 4000
    sch = star_schema(seed=4, n_fact=n_fact, n_dim=32, n_dim_tables=4)
    ms = MaintainedScorer(compile_ensemble(sch, _fit(sch, n_trees=4, depth=3)),
                          counter=QueryCounter())
    rows = []
    for k in ([1, 8] if smoke else [1, 8, 64]):
        dt_i, dt_f, e_i, e_f = _measure(
            ms, "fact", lambda k=k: _update_delta(ms, "dim1", k, rng))
        assert _audit(ms, "fact")
        rows.append({
            "bench": "I2", "delta_rows": k,
            "edges_incremental": e_i, "edges_full": e_f,
            "ms_incremental": round(dt_i, 1), "ms_full": round(dt_f, 1),
            "oracle_exact": True,
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (interpret mode)")
    args = ap.parse_args(argv)
    rows = i1_path_locality(args.smoke) + i2_delta_size_sweep(args.smoke)
    for r in rows:
        print(r)
    widest = max(
        (r for r in rows if r["bench"] == "I1" and r["schema"].startswith("star")),
        key=lambda r: r["edge_ratio"],
    )
    # the asymptotic claim: the widest star's edge ratio equals its width
    ratio = widest["edge_ratio"]
    assert ratio >= 2.0, f"expected path-local refresh, got ratio {ratio}"
    print(f"single-table delta on {widest['schema']}: {ratio}× fewer "
          f"segment-⊕ emissions than full recompute (exact scores)")
    emit("incremental", rows, {
        "edge_ratio_widest_star": ratio,
        "oracle_exact": float(all(r.get("oracle_exact", True) for r in rows)),
    }, config={"smoke": args.smoke})
    return rows


if __name__ == "__main__":
    main()
