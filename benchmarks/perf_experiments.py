import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb runner: hypothesis → change → measure → validate, on
the three chosen cells.  Writes artifacts/perf/<name>.json; the log in
EXPERIMENTS.md §Perf quotes these numbers."""

import json                      # noqa: E402
import sys                       # noqa: E402

sys.path.insert(0, "src")        # noqa: E402
sys.path.insert(0, ".")          # noqa: E402

from benchmarks.roofline import measure_cell, PEAK_FLOPS, HBM_BW, LINK_BW  # noqa: E402


def _summarize(rec):
    return {
        "terms_ms": {k: round(v * 1e3, 1) for k, v in rec["terms_s"].items()},
        "bottleneck": rec["bottleneck"],
        "fraction": round(rec["roofline_fraction"], 4),
        "useful_ratio": round(rec["useful_ratio"], 4),
    }


def recompute_with_pairs(rec, n_pairs_full):
    """Reconstruct the PRE-banding cost from the same measured pieces by
    swapping the attn_pair multiplier (used for the hymba 'before')."""
    mults = dict(rec["multipliers"])
    layer_mult = mults["block_rest"]
    # nq·nk full pairs per layer-execution unit
    mults["attn_pair"] = n_pairs_full * (
        mults["attn_pair"] / max(rec["multipliers"]["attn_pair"], 1e-9)
    ) if False else n_pairs_full
    flops = sum(rec["pieces"][k]["flops"] * m for k, m in mults.items())
    byts = sum(rec["pieces"][k]["bytes"] * m for k, m in mults.items())
    coll = sum(rec["pieces"][k]["coll_bytes"] * m for k, m in mults.items())
    t = {"compute": flops / PEAK_FLOPS, "memory": byts / HBM_BW,
         "collective": coll / LINK_BW}
    ideal = rec["model_flops"] / 256 / PEAK_FLOPS
    return {
        "terms_ms": {k: round(v * 1e3, 1) for k, v in t.items()},
        "bottleneck": max(t, key=t.get),
        "fraction": round(ideal / max(t.values()), 4),
    }


def main():
    os.makedirs("artifacts/perf", exist_ok=True)
    out = {}

    # H-1 hymba train_4k: banded windowed attention (before = full pairs)
    rec = measure_cell("hymba_1_5b", "train_4k")
    seq0 = 512
    nq = nk = (4096 + 128) / seq0
    n_glob = 3
    full_pairs = nq * nk
    out["H1_hymba_banded_attention"] = {
        "before_full_pairs": recompute_with_pairs(rec, full_pairs),
        "after_banded": _summarize(rec),
        "pairs_per_layer": {"before": full_pairs,
                            "after": rec["multipliers"]["attn_pair"]
                            / (32 * 8)},
    }

    # H-2 llama3 train_4k: n_micro 16 → 8 (halve FSDP weight regathers)
    base = measure_cell("llama3_405b", "train_4k")
    opt = measure_cell("llama3_405b", "train_4k", n_micro_override=8)
    out["H2_llama3_n_micro"] = {"nm16": _summarize(base), "nm8": _summarize(opt)}

    # H-3 dbrx train_4k: capacity 1.25 → 1.0 and n_micro 16 → 8
    base = measure_cell("dbrx_132b", "train_4k")
    o1 = measure_cell("dbrx_132b", "train_4k",
                      cfg_overrides={"capacity_factor": 1.0})
    o2 = measure_cell("dbrx_132b", "train_4k",
                      cfg_overrides={"capacity_factor": 1.0},
                      n_micro_override=8)
    out["H3_dbrx_capacity_nmicro"] = {
        "cf1.25_nm16": _summarize(base),
        "cf1.0_nm16": _summarize(o1),
        "cf1.0_nm8": _summarize(o2),
    }

    with open("artifacts/perf/hillclimb.json", "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
