"""Serving benchmark: seed per-leaf scoring loop vs compiled one-pass
scorer, plus micro-batching service throughput.

  S1  SumProd-evaluation counts + bulk wall time, old (per-leaf loop,
      n_trees·L + 1 passes) vs new (stacked-leaf Channels pass, 1),
      with scores cross-checked bit-for-bit against the materialized
      join oracle.
  S2  micro-batching service QPS under zipf-skewed interactive traffic
      (batch coalescing + LRU cache), measured end to end.

    PYTHONPATH=src python benchmarks/bench_serving.py
"""
from __future__ import annotations

import argparse
import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BoostConfig, Booster, QueryCounter, materialize_join, predict_rows,
)
from _common import emit
from repro.relational.generators import star_schema
from repro.serving import (
    ModelRegistry, RelationalScoringService, compile_ensemble,
    score_grouped, score_grouped_reference,
)


def _timeit(fn, n=3):
    jax.block_until_ready(fn())   # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e3  # ms


def s1_one_pass_vs_leaf_loop(n_fact=2000, n_dim=64, n_trees=5, depth=3):
    sch = star_schema(seed=3, n_fact=n_fact, n_dim=n_dim)
    cfg = BoostConfig(n_trees=n_trees, depth=depth, mode="sketch", ssr_mode="off")
    booster = Booster(sch, cfg)
    trees, _ = booster.fit()

    c_old = QueryCounter()
    tot_old, cnt_old = score_grouped_reference(sch, trees, "fact", counter=c_old)
    ms_old = _timeit(lambda: score_grouped_reference(sch, trees, "fact"))

    c_new = QueryCounter()
    ens = compile_ensemble(sch, trees, counter=c_new)
    tot_new, cnt_new = score_grouped(ens, "fact")
    ms_new = _timeit(lambda: ens._score_fn("fact")(ens.factors, ens.leaf_values))

    # oracle: brute force over the materialized join
    J = materialize_join(sch)
    X = jnp.stack([J[c] for (_, c) in sch.features], axis=1)
    rows = np.asarray(J["__rows__fact"])
    preds = np.asarray(predict_rows(trees, X))
    want_tot = np.bincount(rows, weights=preds, minlength=n_fact)
    want_cnt = np.bincount(rows, minlength=n_fact)
    np.testing.assert_allclose(np.asarray(tot_new), want_tot, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(cnt_new), want_cnt, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(tot_new), np.asarray(tot_old),
                               rtol=1e-3, atol=1e-3)

    ratio = c_old.count / max(c_new.count, 1)
    assert ratio >= 5.0, f"expected ≥5× fewer SumProd evaluations, got {ratio:.1f}×"
    return [{
        "bench": "S1", "n_fact": n_fact, "trees": n_trees, "leaves": 2 ** depth,
        "sumprod_evals_old": c_old.count, "sumprod_evals_new": c_new.count,
        "eval_ratio": round(ratio, 1),
        "bulk_ms_old": round(ms_old, 1), "bulk_ms_new": round(ms_new, 1),
        "oracle_match": True,
    }], sch, trees


def s2_service_qps(sch, trees, n_requests=2000, max_batch=64, max_wait_ms=1.0,
                   cache_size=4096, zipf_a=1.3):
    registry = ModelRegistry()
    registry.publish(compile_ensemble(sch, trees))
    service = RelationalScoringService(
        registry, "fact", max_batch=max_batch, max_wait_ms=max_wait_ms,
        cache_size=cache_size,
    )
    n_rows = sch.table("fact").n_rows
    rng = np.random.default_rng(1)
    ids = np.minimum(rng.zipf(zipf_a, n_requests) - 1, n_rows - 1)

    async def run():
        await service.start()
        await service.score_many(ids[:64].tolist())   # warm the jit + cache
        t0 = time.perf_counter()
        for chunk in np.array_split(ids, max(1, n_requests // 256)):
            await service.score_many(chunk.tolist())
        dt = time.perf_counter() - t0
        await service.stop()
        return dt

    dt = asyncio.run(run())
    snap = service.stats_snapshot()
    return [{
        "bench": "S2", "requests": n_requests, "wall_s": round(dt, 3),
        "qps": int(n_requests / dt),
        "batches": snap["batches"], "mean_batch": round(snap["mean_batch"], 1),
        "cache_hit_pct": round(100 * snap["cache_hit_rate"], 1),
        "latency_ms_p50": round(snap["latency_ms"]["p50"], 3),
        "latency_ms_p99": round(snap["latency_ms"]["p99"], 3),
        "queue_wait_ms_p50": round(snap["queue_wait_ms"]["p50"], 3),
        "queue_wait_ms_p99": round(snap["queue_wait_ms"]["p99"], 3),
    }]


def run_all(fast: bool = True):
    rows, sch, trees = s1_one_pass_vs_leaf_loop(
        n_fact=1000 if fast else 4000, n_trees=4 if fast else 6,
        depth=3,
    )
    rows += s2_service_qps(sch, trees, n_requests=1000 if fast else 5000)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    rows = run_all(fast=not args.full)
    for r in rows:
        print(r)
    s1 = next(r for r in rows if r["bench"] == "S1")
    s2 = next(r for r in rows if r["bench"] == "S2")
    emit("serving", rows, {
        "eval_ratio": s1["eval_ratio"],
        "qps": s2["qps"],
        "cache_hit_pct": s2["cache_hit_pct"],
        "latency_ms_p99": s2["latency_ms_p99"],
    }, config={"full": args.full})
    return rows


if __name__ == "__main__":
    main()
