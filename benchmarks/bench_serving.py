"""Serving benchmark: seed per-leaf scoring loop vs compiled one-pass
scorer, plus micro-batching service throughput.

  S1  SumProd-evaluation counts + bulk wall time, old (per-leaf loop,
      n_trees·L + 1 passes) vs new (stacked-leaf Channels pass, 1),
      with scores cross-checked bit-for-bit against the materialized
      join oracle.
  S2  micro-batching service QPS under zipf-skewed interactive traffic
      (batch coalescing + LRU cache), measured end to end.
  S3  open-loop mixed delta+query workload under SLO burn-rate
      monitoring: interleaved table deltas and scoring chunks with a
      healthy-phase compliance measurement, then an injected dispatch
      latency spike that must flip the burn-rate state off healthy AND
      trigger a flight-recorder dump (validated as a loadable Chrome
      trace).  The SLO summary fields land in BENCH_serving.json so
      report.py --check gates on them.
  S4  data-parallel scaling: the same ensemble compiled unsharded and
      row-sharded over every visible device (CI forces 8 host devices
      via XLA_FLAGS), with grouped scores required bit-equal and the
      segment-⊕ edge count identical — sharding may move work, never
      change it.  Single-device runs emit the 1.0 identity point.
  S5  snapshot isolation under concurrent ingest: a real ingest thread
      applies deltas while the service scores, every batch dispatching
      against an MVCC snapshot pinned at cutoff; post-run, every LRU
      cache entry must bit-match the recompute oracle at the
      data_version in its own key, with the SLO monitor healthy
      end-to-end.
  S6  durability: the same delta stream applied with and without a
      group-committed WAL attached (append overhead %), a follower
      process tailing the log into a live replica (replication lag
      p99), and a timed cold recovery from checkpoint + WAL tail —
      replica and recovered scorer must both serve bit-identically to
      the writer.

    PYTHONPATH=src python benchmarks/bench_serving.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python benchmarks/bench_serving.py --smoke
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BoostConfig, Booster, QueryCounter, materialize_join, predict_rows,
)
from _common import REPO_ROOT, emit
from repro.incremental import MaintainedScorer
from repro.obs import FlightRecorder, SLOMonitor, get_tracer, parse_slo_spec
from repro.relational.generators import delta_stream, star_schema
from repro.serving import (
    ModelRegistry, RelationalScoringService, compile_ensemble,
    score_grouped, score_grouped_reference,
)


def _timeit(fn, n=3):
    jax.block_until_ready(fn())   # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e3  # ms


def s1_one_pass_vs_leaf_loop(n_fact=2000, n_dim=64, n_trees=5, depth=3):
    sch = star_schema(seed=3, n_fact=n_fact, n_dim=n_dim)
    cfg = BoostConfig(n_trees=n_trees, depth=depth, mode="sketch", ssr_mode="off")
    booster = Booster(sch, cfg)
    trees, _ = booster.fit()

    c_old = QueryCounter()
    tot_old, cnt_old = score_grouped_reference(sch, trees, "fact", counter=c_old)
    ms_old = _timeit(lambda: score_grouped_reference(sch, trees, "fact"))

    c_new = QueryCounter()
    ens = compile_ensemble(sch, trees, counter=c_new)
    tot_new, cnt_new = score_grouped(ens, "fact")
    ms_new = _timeit(lambda: ens._score_fn("fact")(ens.factors, ens.leaf_values))

    # oracle: brute force over the materialized join
    J = materialize_join(sch)
    X = jnp.stack([J[c] for (_, c) in sch.features], axis=1)
    rows = np.asarray(J["__rows__fact"])
    preds = np.asarray(predict_rows(trees, X))
    want_tot = np.bincount(rows, weights=preds, minlength=n_fact)
    want_cnt = np.bincount(rows, minlength=n_fact)
    np.testing.assert_allclose(np.asarray(tot_new), want_tot, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(cnt_new), want_cnt, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(tot_new), np.asarray(tot_old),
                               rtol=1e-3, atol=1e-3)

    ratio = c_old.count / max(c_new.count, 1)
    assert ratio >= 5.0, f"expected ≥5× fewer SumProd evaluations, got {ratio:.1f}×"
    return [{
        "bench": "S1", "n_fact": n_fact, "trees": n_trees, "leaves": 2 ** depth,
        "sumprod_evals_old": c_old.count, "sumprod_evals_new": c_new.count,
        "eval_ratio": round(ratio, 1),
        "bulk_ms_old": round(ms_old, 1), "bulk_ms_new": round(ms_new, 1),
        "oracle_match": True,
    }], sch, trees


def s2_service_qps(sch, trees, n_requests=2000, max_batch=64, max_wait_ms=1.0,
                   cache_size=4096, zipf_a=1.3):
    registry = ModelRegistry()
    registry.publish(compile_ensemble(sch, trees))
    service = RelationalScoringService(
        registry, "fact", max_batch=max_batch, max_wait_ms=max_wait_ms,
        cache_size=cache_size,
    )
    n_rows = sch.table("fact").n_rows
    rng = np.random.default_rng(1)
    ids = np.minimum(rng.zipf(zipf_a, n_requests) - 1, n_rows - 1)

    async def run():
        await service.start()
        await service.score_many(ids[:64].tolist())   # warm the jit + cache
        t0 = time.perf_counter()
        for chunk in np.array_split(ids, max(1, n_requests // 256)):
            await service.score_many(chunk.tolist())
        dt = time.perf_counter() - t0
        await service.stop()
        return dt

    dt = asyncio.run(run())
    snap = service.stats_snapshot()
    return [{
        "bench": "S2", "requests": n_requests, "wall_s": round(dt, 3),
        "qps": int(n_requests / dt),
        "batches": snap["batches"], "mean_batch": round(snap["mean_batch"], 1),
        "cache_hit_pct": round(100 * snap["cache_hit_rate"], 1),
        "latency_ms_p50": round(snap["latency_ms"]["p50"], 3),
        "latency_ms_p99": round(snap["latency_ms"]["p99"], 3),
        "queue_wait_ms_p50": round(snap["queue_wait_ms"]["p50"], 3),
        "queue_wait_ms_p99": round(snap["queue_wait_ms"]["p99"], 3),
    }]


def s3_slo_mixed_workload(sch, trees, n_clean=8, n_spike=4, chunk=64,
                          spike_sleep_s=0.6):
    """Open-loop mixed delta+query run with SLO monitoring.

    Clean phase: interleave delta batches (MaintainedScorer.apply) with
    score_many chunks and measure latency compliance.  Spike phase: wrap
    the service's dispatch in a sleep so every request blows the latency
    objective — the burn-rate state must leave ``healthy`` and the
    flight recorder must dump a valid Chrome trace.
    """
    out_dir = os.environ.get("REPRO_BENCH_DIR") or REPO_ROOT
    tracer = get_tracer()
    was_enabled = tracer.enabled          # REPRO_TRACE=1 in CI
    slo = SLOMonitor(parse_slo_spec("latency=300ms@0.9,errors=0.05,staleness=10s"),
                     fast_window_s=2.0, slow_window_s=8.0)
    flight = FlightRecorder(capacity=2048, out_dir=out_dir, name="serving",
                            latency_trigger_ms=450.0, cooldown_s=0.3).start()
    registry = ModelRegistry()
    ms = MaintainedScorer(compile_ensemble(sch, trees))
    registry.publish(ms)
    # shed_when_unhealthy off: the bench drives PAST the SLO on purpose
    # and wants latencies, not ServiceOverloadedError, from the far side
    service = RelationalScoringService(
        registry, "fact", max_batch=chunk, max_wait_ms=0.5, cache_size=256,
        flight=flight, shed_when_unhealthy=False,
    )
    n_rows = sch.table("fact").n_rows
    rng = np.random.default_rng(5)
    deltas = list(delta_stream(sch, ms.live_rows, seed=11,
                               n_batches=n_clean, ops_per_batch=4))

    async def run():
        await service.start()
        # warm the jit + message cache before the SLO clock starts
        await service.score_many(rng.integers(0, n_rows, chunk).tolist())
        service.slo = slo
        max_stale = 0.0
        for batch in deltas:              # clean phase: deltas + queries
            ms.apply(batch)
            max_stale = max(max_stale, ms.staleness_s())
            ids = np.minimum(rng.zipf(1.3, chunk) - 1, n_rows - 1)
            await service.score_many(ids.tolist())
        clean_state = slo.state()
        clean_compliance = slo.compliance("latency")
        # spike phase: every dispatch stalls past the latency objective
        orig = service._dispatch
        service._dispatch = lambda b: (time.sleep(spike_sleep_s), orig(b))[1]
        for _ in range(n_spike):
            ids = rng.integers(0, n_rows, chunk)
            await service.score_many(ids.tolist())
        service._dispatch = orig
        spike_state = slo.state()
        await service.stop()
        return clean_state, clean_compliance, spike_state, max_stale

    clean_state, clean_compliance, spike_state, max_stale = asyncio.run(run())
    flight.stop()
    if was_enabled:
        tracer.enabled = True             # keep the CI TRACE dump alive

    dumps = [d for d in flight.status()["dumps"] if d["path"]]
    assert spike_state != "healthy", (
        f"latency spike did not move the burn-rate state: {spike_state}")
    assert dumps, "latency spike did not trigger a flight dump"
    with open(dumps[0]["path"]) as f:     # must load as a Chrome trace
        doc = json.load(f)
    events = doc["traceEvents"]
    triggers = [e for e in events if e.get("name") == "flight.trigger"]
    assert triggers and triggers[0]["ph"] == "i", "dump lacks trigger marker"
    snap = service.stats_snapshot()
    return [{
        "bench": "S3", "deltas": len(deltas), "requests": snap["requests"],
        "clean_state": clean_state,
        "clean_latency_compliance": round(clean_compliance, 4),
        "max_staleness_s": round(max_stale, 4),
        "spike_state": spike_state,
        "flight_dumps": len(dumps), "flight_events": len(events),
        "errors": snap["errors"], "shed": snap["shed"],
    }]


def s5_snapshot_isolation(sch, trees, n_batches=6, chunk=48, ops_per_batch=4):
    """Concurrent ingest + serve under MVCC snapshot isolation.

    A REAL ingest thread applies delta batches against the published
    MaintainedScorer while the asyncio service scores zipf traffic with
    the full backpressure stack on (SLO-fed admission control, queue
    depth cap, deadline-aware batch cutoff).  Every applied version pins
    a ``pin_oracle=True`` snapshot; after the run EVERY entry in the
    service's LRU cache must match the full-recompute oracle at the
    data_version in its own key, bit for bit — a single mixed-version
    score fails the bench.  The SLO monitor must end the run healthy:
    isolation is only interesting if it holds while latency/staleness
    stay within objective.  The latency objective is sized for this
    workload's worst case — every new data_version re-jits the
    path-restricted refresh for its new message/factor shapes, so the
    first batch per version carries a compile — which keeps admission
    control armed without the bench shedding itself on compile spikes.
    """
    slo = SLOMonitor(parse_slo_spec("latency=2000ms@0.9,errors=0.05,staleness=10s"),
                     fast_window_s=2.0, slow_window_s=8.0)
    registry = ModelRegistry()
    ms = MaintainedScorer(compile_ensemble(sch, trees))
    group = "fact"
    v = registry.publish(ms)
    # the SLO attaches after warm-up (below), so the deadline budget is
    # passed explicitly — the cutoff must be live from the first batch
    service = RelationalScoringService(
        registry, group, max_batch=chunk, max_wait_ms=0.5, cache_size=8192,
        max_queue=256, latency_budget_ms=2000.0,
    )
    rng = np.random.default_rng(7)
    oracles = {}
    n0 = sch.table(group).n_rows

    import threading

    async def run():
        await service.start()
        # warm jit + message cache, pin the version-0 oracle, THEN attach
        # the SLO monitor so compile time doesn't burn the latency budget
        await service.score_many(rng.integers(0, n0, chunk).tolist())
        oracles[0] = ms.snapshot(roots=(group,), pin_oracle=True)
        service.slo = slo
        done = threading.Event()

        def ingest():
            # the stream is LAZY on live_rows — batches must be generated
            # against the rows they will apply to, version by version
            for batch in delta_stream(sch, ms.live_rows, seed=13,
                                      n_batches=n_batches,
                                      ops_per_batch=ops_per_batch):
                ms.apply(batch)
                oracles[ms.data_version] = ms.snapshot(roots=(group,),
                                                       pin_oracle=True)
                time.sleep(0.004)
            done.set()

        t = threading.Thread(target=ingest)
        t.start()
        max_stale = 0.0
        while not done.is_set():
            ids = np.minimum(rng.zipf(1.3, chunk) - 1, n0 - 1)
            await service.score_many(ids.tolist())
            max_stale = max(max_stale, service.stats.staleness_s.value)
        t.join()
        # one post-ingest round guarantees final-version cache entries
        await service.score_many(rng.integers(0, n0, chunk).tolist())
        await service.stop()
        return max_stale

    max_stale = asyncio.run(run())
    end_state = slo.state()
    compliance = slo.compliance("latency")

    # the isolation audit: every cached score vs the oracle pinned at
    # the data_version baked into its own cache key
    means = {}
    audited = 0
    for (kv, ep, dv, row), val in service.cache._d.items():
        assert kv == v and ep == registry.epoch(v)
        if dv not in means:
            tot, cnt = oracles[dv].recompute_oracle(group)
            tot, cnt = np.asarray(tot), np.asarray(cnt)
            means[dv] = (tot / np.maximum(cnt, np.float32(1.0))).astype(np.float32)
        assert val == float(means[dv][row]), (
            f"cached score at data_version {dv} row {row} does not match "
            f"its pinned recompute oracle — snapshot isolation violated")
        audited += 1
    assert len(means) > 1, "audit never spanned a version boundary"
    assert end_state == "healthy", (
        f"SLO left healthy under concurrent ingest: {end_state}")
    assert max_stale <= 10.0, f"staleness blew the objective: {max_stale:.3f}s"

    snap = service.stats_snapshot()
    return [{
        "bench": "S5", "deltas": n_batches, "requests": snap["requests"],
        "versions_audited": len(means), "cache_entries_audited": audited,
        "isolation_exact": True,
        "mixed_latency_compliance": round(compliance, 4),
        "latency_ms_p50": round(snap["latency_ms"]["p50"], 3),
        "latency_ms_p99": round(snap["latency_ms"]["p99"], 3),
        "max_staleness_s": round(max_stale, 4),
        "end_state": end_state,
        "errors": snap["errors"], "shed": snap["shed"],
    }]


def s4_sharded_scaling(n_fact=131072, n_dim=64, n_trees=4, depth=3):
    """Row-sharded vs unsharded scoring of one ensemble.

    The compiled factors carry integer-valued leaf-membership counts, so
    the cross-shard segment-⊕ re-association is exact: grouped scores
    must match the single-device run bit for bit, and the host-side edge
    accounting must be untouched by where the rows live.  The headline
    ``qps_scaling`` is bulk-pass throughput sharded ÷ unsharded.

    Trees are fit on a small fact table and compiled against a large one
    (the feature list of a star schema is fact-size independent): the
    bench times the serving regime where sharding pays — the per-row
    segment-⊕ over a big fact factor — without paying a big training
    run.  Small-problem sharding IS slower (collective setup dominates
    sub-ms passes); that regime is covered by the bit-equality tests,
    not timed here.
    """
    from repro.distributed import spmd
    from repro.launch.mesh import make_data_mesh

    n_dev = jax.device_count()
    train_sch = star_schema(seed=9, n_fact=1024, n_dim=n_dim)
    cfg = BoostConfig(n_trees=n_trees, depth=depth, mode="sketch",
                      ssr_mode="off")
    trees, _ = Booster(train_sch, cfg).fit()
    sch = star_schema(seed=9, n_fact=n_fact, n_dim=n_dim)

    c1 = QueryCounter()
    ens1 = compile_ensemble(sch, trees, counter=c1)
    tot1, cnt1 = score_grouped(ens1, "fact")
    e1 = c1.edges
    ms1 = _timeit(lambda: score_grouped(ens1, "fact"), n=5)

    row = {"bench": "S4", "devices": n_dev, "n_fact": n_fact,
           "bulk_ms_1dev": round(ms1, 1)}
    if n_dev == 1:
        row.update(bulk_ms_ndev=round(ms1, 1), qps_scaling=1.0,
                   bit_equal=True, edges_equal=True)
        return [row]

    mesh = make_data_mesh()
    cN = QueryCounter()
    with spmd.use_data_mesh(mesh):
        ensN = compile_ensemble(sch, trees, counter=cN)
    assert spmd.is_row_sharded(ensN.factors["fact"], mesh), \
        "fact factor did not shard"
    totN, cntN = score_grouped(ensN, "fact")
    eN = cN.edges
    msN = _timeit(lambda: score_grouped(ensN, "fact"), n=5)

    bit_equal = (np.array_equal(np.asarray(tot1), np.asarray(totN))
                 and np.array_equal(np.asarray(cnt1), np.asarray(cntN)))
    assert bit_equal, "sharded grouped scores diverged from single-device"
    assert e1 == eN, f"sharding changed the counted work: {e1} vs {eN}"
    row.update(bulk_ms_ndev=round(msN, 1),
               qps_scaling=round(ms1 / msN, 3),
               bit_equal=True, edges_equal=True)
    return [row]


def s6_durability(sch, trees, n_batches=16, ops_per_batch=4):
    """Durable delta log: append overhead, recovery time, replication lag.

    One deterministic delta stream (same seed ⇒ bit-identical batches)
    drives three apply loops: a warm-up (jit/compile caches), a measured
    loop with a group-committed :class:`WalWriter` attached, and an
    untimed replication loop where a :class:`WalFollower` tails a
    streaming writer into a live replica.  The overhead metric is read
    from the ``wal.append_ms`` histogram — the time actually spent
    inside ``append()`` (encode + CRC + write + group-commit fsyncs) as
    a fraction of the rest of the ingest loop — because differencing
    two whole apply loops buries the sub-ms append cost under jit
    dispatch noise.  A checkpoint lands mid-stream; after the writer
    closes, the full recovery path (newest checkpoint + WAL-tail
    replay) is timed cold.

    Invariants asserted inline: the follower replica, the recovered
    scorer, and the writer all serve bit-identical grouped scores at the
    final data_version.  Headline metrics — ``wal_append_overhead_pct``,
    ``recovery_replay_s``, ``replication_lag_p99_s`` — are pinned in
    baselines.json and gated by report.py --check.
    """
    import shutil
    import tempfile

    from repro.incremental.recover import recover_scorer, save_checkpoint
    from repro.incremental.wal import WalFollower, WalWriter
    from repro.obs import get_registry

    group = "fact"

    def apply_loop(ms, on_batch=None):
        """Apply the canonical stream; returns summed apply() seconds."""
        total = 0.0
        for bi, batch in enumerate(delta_stream(
                sch, ms.live_rows, seed=29, n_batches=n_batches,
                ops_per_batch=ops_per_batch)):
            t0 = time.perf_counter()
            ms.apply(batch)
            total += time.perf_counter() - t0
            if on_batch is not None:
                on_batch(bi, ms)
        return total

    # warm-up: same stream, same shapes — populates every jit cache the
    # measured loops will hit
    apply_loop(MaintainedScorer(compile_ensemble(sch, trees)))

    wal_dir = tempfile.mkdtemp(prefix="bench_wal_")
    ckpt_dir = os.path.join(wal_dir, "ckpt")
    rep_dir = tempfile.mkdtemp(prefix="bench_wal_follow_")
    try:
        # measured WAL pass — writer only, so the timing isolates the
        # append path (replication runs as its own phase below: a live
        # follower competes for the interpreter and would bill its
        # apply work to the writer loop)
        # count-based group commit only: the default 50ms interval flush
        # is an idle-writer latency bound, but at this loop's batch
        # cadence (slower than 50ms/batch) it degenerates to an fsync
        # per append and the metric stops measuring the append path
        ms_wal = MaintainedScorer(compile_ensemble(sch, trees))
        wal = WalWriter(wal_dir, sync_every=8,
                        sync_interval_s=60.0).attach(ms_wal.state)

        def on_batch(bi, ms):
            if bi + 1 == n_batches // 2:
                save_checkpoint(ms.state, ckpt_dir)

        h_append = get_registry().histogram("wal.append_ms")
        append_ms0 = h_append.sum
        t_wal = apply_loop(ms_wal, on_batch=on_batch)
        append_s = (h_append.sum - append_ms0) / 1e3
        wal.heartbeat()
        wal.sync()
        wal.close()
        want_t, want_c = ms_wal.grouped_cached(group)
        assert ms_wal.data_version == n_batches

        # cold recovery: newest checkpoint + WAL-tail replay
        t0 = time.perf_counter()
        recovered, rep = recover_scorer(
            compile_ensemble(sch, trees), wal_dir, ckpt_dir)
        recovery_s = time.perf_counter() - t0
        assert rep.recovered_lsn == ms_wal.data_version
        rec_t, rec_c = recovered.grouped_cached(group)
        assert (np.array_equal(np.asarray(want_t), np.asarray(rec_t))
                and np.array_equal(np.asarray(want_c), np.asarray(rec_c))), \
            "recovered scorer diverged from the writer"

        overhead_pct = 100.0 * append_s / max(t_wal - append_s, 1e-9)

        # replication phase (untimed): a live follower tails a streaming
        # writer into a second scorer; apply-lag is measured per record
        # from its WAL wall-clock stamp
        ms_src = MaintainedScorer(compile_ensemble(sch, trees))
        replica = MaintainedScorer(compile_ensemble(sch, trees))
        wal2 = WalWriter(rep_dir, sync_every=8).attach(ms_src.state)
        follower = WalFollower(rep_dir, replica.apply,
                               poll_interval_s=0.005).start()
        apply_loop(ms_src)
        wal2.heartbeat()
        wal2.sync()
        wal2.close()
        follower.stop(drain=True)
        src_t, src_c = ms_src.grouped_cached(group)
        got_t, got_c = replica.grouped_cached(group)
        assert (np.array_equal(np.asarray(src_t), np.asarray(got_t))
                and np.array_equal(np.asarray(src_c), np.asarray(got_c))), \
            "follower replica diverged from the writer"
        assert replica.data_version == ms_src.data_version == n_batches
        lag_p99 = get_registry().histogram(
            "wal.follower.apply_lag_s").quantile(0.99)

        return [{
            "bench": "S6", "deltas": n_batches,
            "apply_s_wal": round(t_wal, 4),
            "wal_append_s": round(append_s, 4),
            "wal_append_overhead_pct": round(overhead_pct, 2),
            "wal_bytes": os.path.getsize(wal.path),
            "checkpoint_lsn": rep.checkpoint_lsn,
            "replayed": rep.replayed,
            "recovery_replay_s": round(recovery_s, 4),
            "replication_lag_p99_s": round(lag_p99, 4),
            "replica_bit_equal": True, "recovered_bit_equal": True,
        }]
    finally:
        shutil.rmtree(wal_dir, ignore_errors=True)
        shutil.rmtree(rep_dir, ignore_errors=True)


def run_all(fast: bool = True):
    rows, sch, trees = s1_one_pass_vs_leaf_loop(
        n_fact=1000 if fast else 4000, n_trees=4 if fast else 6,
        depth=3,
    )
    rows += s2_service_qps(sch, trees, n_requests=1000 if fast else 5000)
    rows += s3_slo_mixed_workload(sch, trees, n_clean=6 if fast else 10,
                                  n_spike=4 if fast else 6)
    rows += s4_sharded_scaling(n_fact=131072 if fast else 262144)
    rows += s5_snapshot_isolation(sch, trees, n_batches=6 if fast else 12)
    rows += s6_durability(sch, trees, n_batches=16 if fast else 40)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="fast sizes (the default; named for CI legs)")
    args = ap.parse_args(argv)
    rows = run_all(fast=not args.full)
    for r in rows:
        print(r)
    s1 = next(r for r in rows if r["bench"] == "S1")
    s2 = next(r for r in rows if r["bench"] == "S2")
    s3 = next(r for r in rows if r["bench"] == "S3")
    s4 = next(r for r in rows if r["bench"] == "S4")
    s5 = next(r for r in rows if r["bench"] == "S5")
    s6 = next(r for r in rows if r["bench"] == "S6")
    emit("serving", rows, {
        "eval_ratio": s1["eval_ratio"],
        "qps": s2["qps"],
        "cache_hit_pct": s2["cache_hit_pct"],
        "latency_ms_p99": s2["latency_ms_p99"],
        "slo_latency_compliance": s3["clean_latency_compliance"],
        "slo_spike_detected": 1.0 if (s3["spike_state"] != "healthy"
                                      and s3["flight_dumps"] > 0) else 0.0,
        "qps_scaling_8dev": s4["qps_scaling"],
        "mixed_latency_compliance": s5["mixed_latency_compliance"],
        "snapshot_isolation_exact": 1.0 if (s5["isolation_exact"]
                                            and s5["end_state"] == "healthy")
                                    else 0.0,
        "wal_append_overhead_pct": s6["wal_append_overhead_pct"],
        "recovery_replay_s": s6["recovery_replay_s"],
        "replication_lag_p99_s": s6["replication_lag_p99_s"],
        "durability_exact": 1.0 if (s6["replica_bit_equal"]
                                    and s6["recovered_bit_equal"]) else 0.0,
    }, config={"full": args.full, "devices": jax.device_count()})
    return rows


if __name__ == "__main__":
    main()
