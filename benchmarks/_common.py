"""Shared BENCH_*.json emission for the benchmark smokes.

Every ``bench_*.py`` funnels its result rows and headline metrics
through :func:`emit`, which writes the schema-versioned
``BENCH_<name>.json`` at the repo root (override with
``REPRO_BENCH_DIR``).  Committing the artifacts is the perf trajectory;
``benchmarks/report.py --check`` gates CI on them.  Headline metrics
should prefer counted work (query/edge ratios) over wall-clock — they
are scheduler-noise free and safe to pin.
"""
from __future__ import annotations

import os

from repro.obs import BenchReport, enable_tracing

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# REPRO_TRACE=1 turns every span on for the whole bench run (the bench
# modules import this first), so CI uploads a TRACE_<name>.jsonl next to
# each BENCH file without per-bench flags
if os.environ.get("REPRO_TRACE"):
    enable_tracing()


def emit(name: str, rows, metrics: dict, config: dict = None) -> str:
    rep = BenchReport(name, config=config)
    rep.add_rows(list(rows))
    for k, v in metrics.items():
        rep.set_metric(k, v)
    path = rep.write(os.environ.get("REPRO_BENCH_DIR") or REPO_ROOT)
    print(f"wrote {path}")
    return path
