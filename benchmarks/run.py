"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper claim (see bench_paper.py) printed as
``name,us_per_call,derived`` CSV rows, plus a roofline-table summary if
dry-run/roofline artifacts exist (those are produced by the 512-device
processes: launch/dryrun.py and benchmarks/roofline.py).
"""
from __future__ import annotations

import glob
import json
import os
import sys


def main() -> None:
    fast = "--full" not in sys.argv
    from . import bench_paper, bench_serving

    rows = bench_paper.run_all(fast=fast) + bench_serving.run_all(fast=fast)
    print("name,us_per_call,derived")
    for r in rows:
        name = r.pop("bench")
        sub = "_".join(
            f"{k}={v}" for k, v in r.items()
            if k in ("mode", "L", "k", "rows", "domain")
        )
        us = r.get("grouped_query_us") or r.get("grouped_sketch_query_us") or (
            r.get("wall_s", r.get("fit_wall_s", 0)) * 1e6
        )
        derived = {k: v for k, v in r.items()
                   if k not in ("grouped_query_us", "grouped_sketch_query_us")}
        print(f"{name}[{sub}],{us},{derived}")

    # roofline summary (artifacts written by benchmarks/roofline.py)
    arts = sorted(glob.glob("artifacts/roofline/*.json"))
    if arts:
        print("\nname,us_per_call,derived  # roofline terms per cell (derived)")
        for p in arts:
            r = json.load(open(p))
            t = r["terms_s"]
            step_us = max(t.values()) * 1e6
            print(f"roofline[{r['arch']}|{r['shape']}|{r['mesh']}],{step_us:.1f},"
                  f"{{'bottleneck': '{r['bottleneck']}', "
                  f"'fraction': {r['roofline_fraction']:.3f}, "
                  f"'useful_ratio': {r['useful_ratio']:.3f}}}")
    if os.path.exists("artifacts/dryrun"):
        n = len(glob.glob("artifacts/dryrun/*.json"))
        e = len(glob.glob("artifacts/dryrun/*.err"))
        print(f"\n# dry-run artifacts: {n} cells ok, {e} errors (see EXPERIMENTS.md)")


if __name__ == "__main__":
    main()
