import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# ^ 512 placeholder devices, same rule as launch/dryrun.py (run standalone).

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
from functools import partial  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                                   # noqa: E402
from repro.distributed.sharding import (                    # noqa: E402
    cache_shardings, logical_to_spec, mesh_axes, param_shardings,
)
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.launch.steps import n_micro as micro_of          # noqa: E402
from repro.models import Model                              # noqa: E402
from repro.models import lm as LM                           # noqa: E402
from repro.models import layers as LYR                      # noqa: E402
from repro.optim import adamw                               # noqa: E402

"""Roofline analysis (EXPERIMENTS.md §Roofline).

``compiled.cost_analysis()`` reports per-device numbers and counts while
bodies ONCE (measured in DESIGN.md §6), so the cost model here composes
loop-free *pieces*, each lowered at the true sharded shapes on the true
mesh:

  train   = n_micro · [ L · layer_vjp + embed+head+loss_vjp ] + optimizer
  prefill = L · layer_fwd + embed+head
  decode  = L · layer_decode + embed+head

Per-cell outputs: the three roofline terms (seconds), dominant term,
MODEL_FLOPS = 6·N·D (2·N_active·D decode/prefill), useful-compute ratio,
and estimated roofline fraction.  v5e: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DT = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1,
       "f64": 8, "s8": 1, "u8": 1, "c64": 8, "s64": 8, "u64": 8}


def collective_bytes_per_device(hlo: str) -> dict:
    """Ring-model per-device link traffic from loop-free partitioned HLO."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    pat = re.compile(
        r"=\s*(\w+)\[([\d,]*)\]\S*\s+(all-gather|all-reduce|reduce-scatter"
        r"|all-to-all|collective-permute)[^\n]*")
    for m in pat.finditer(hlo):
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        line = m.group(0)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        size = n * _DT.get(dt, 4)
        g = 1
        gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
        if gm:
            g = int(gm.group(2))
        else:
            gm = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
            if gm:
                g = len(gm.group(1).split(","))
        if g <= 1:
            continue
        if op == "all-gather":
            out[op] += size * (g - 1) / g          # size = gathered result
        elif op == "all-reduce":
            out[op] += 2 * size * (g - 1) / g
        elif op == "reduce-scatter":
            out[op] += size * (g - 1)              # size = scattered result
        elif op == "all-to-all":
            out[op] += size * (g - 1) / g
        else:
            out[op] += size
    return out


def piece_cost(fn, in_shardings, args, mesh, donate=()):
    """(flops, bytes, collective seconds, hlo) for one loop-free piece."""
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_shardings).lower(*args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes_per_device(hlo)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": sum(coll.values()),
        "coll_detail": coll,
    }


def _count_params(cfg, params_shape):
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        keys = "/".join(str(getattr(k, "key", getattr(k, "idx", ""))) for k in path)
        n = int(np.prod(leaf.shape))
        total += n
        if "moe/w_" in keys and cfg.n_experts:
            active += n * cfg.top_k / cfg.n_experts
        else:
            active += n
    return total, active


def measure_cell(arch: str, shape_name: str, multi_pod: bool = False,
                 cfg_overrides=None, n_micro_override=None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    cfg = configs.get(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    model = Model(cfg)
    shape = configs.SHAPES[shape_name]
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    la = mesh_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in la["dp"]]))

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total_p, active_p = _count_params(cfg, params_shape)
    layer_shape = jax.eval_shape(
        lambda k: (LM.init_cross_block if cfg.is_encdec else LM.init_block)(
            k, cfg, dt), jax.random.PRNGKey(0))
    lshard = param_shardings(mesh, layer_shape)

    B, S = shape.global_batch, shape.seq_len
    L = cfg.n_layers + (cfg.enc_layers if cfg.is_encdec else 0)
    win = jnp.int32(cfg.window or LYR.GLOBAL_WINDOW)

    def xsharding(bsz, seq):
        return NamedSharding(mesh, logical_to_spec(
            mesh, ("dp", "tp", None), (bsz, seq, cfg.d_model)))

    pieces = {}
    if shape.mode == "train":
        nm = n_micro_override or micro_of(arch, B, dp)
        mb, seq = B // nm, (S // 2 if cfg.is_encdec else S)

        # --- loop-free decomposition (inner attention/recurrence scans are
        # while loops → counted once by cost_analysis, DESIGN.md §6):
        #   A: one block at S0 tokens (single attn block pair inside)
        #   P: one (S0 × S0) attention block pair alone (fwd+bwd)
        #   layer(seq) = (seq/S0)·(A − P) + n_pairs·P
        # n_pairs reflects the implementation's true block schedule
        # (full nq·nk baseline; banded when a static window restricts it).
        S0 = min(512, seq)
        cfg0 = cfg.replace(q_chunk=S0, kv_chunk=S0, ssm_chunk=cfg.ssm_chunk)
        x0 = jax.ShapeDtypeStruct((mb, S0, cfg.d_model), dt)
        pos0 = jnp.zeros((mb, S0), jnp.int32)

        def block_vjp(p, xx):
            f = lambda p_, x_: LM.block_train(p_, cfg0, x_, pos0, win)[0]
            y, vjp = jax.vjp(f, p, xx)
            return vjp(y)

        A = piece_cost(block_vjp, (lshard, xsharding(mb, S0)), (layer_shape, x0), mesh)
        if cfg.kind == "rwkv":
            Pp = {k: 0.0 for k in ("flops", "bytes", "coll_bytes")}
            n_pairs = seq / S0  # recurrence is linear: A scales directly
        else:
            N, Kh, dh = cfg.n_heads, cfg.kv_heads, cfg.head_dim
            qs = jax.ShapeDtypeStruct((mb, S0, N, dh), dt)
            ks = jax.ShapeDtypeStruct((mb, S0, Kh, dh), dt)

            def attn_vjp(q, k, v):
                f = lambda q_, k_, v_: LYR._block_attn(
                    q_, k_, v_, pos0, pos0, True, None, S0, S0)
                y, vjp = jax.vjp(f, q, k, v)
                return vjp(y)

            qsh = NamedSharding(mesh, logical_to_spec(
                mesh, ("dp", None, "tp", None), (mb, S0, N, dh)))
            ksh = NamedSharding(mesh, logical_to_spec(
                mesh, ("dp", None, "tp", None), (mb, S0, Kh, dh)))
            Pp = piece_cost(attn_vjp, (qsh, ksh, ksh), (qs, ks, ks), mesh)
            nq = -(-seq // cfg.q_chunk) * (cfg.q_chunk / S0)
            nk = -(-seq // cfg.kv_chunk) * (cfg.kv_chunk / S0)
            if cfg.window and not cfg.global_layers:
                nk_local = min(nk, -(-(cfg.window + cfg.q_chunk) // S0) + 1)
                n_pairs = nq * nk_local
            elif cfg.window:  # mixed global/local stack: weighted average
                n_glob = len(cfg.global_layers)
                nk_local = min(nk, -(-(cfg.window + cfg.q_chunk) // S0) + 1)
                n_pairs = (n_glob * nq * nk
                           + (cfg.n_layers - n_glob) * nq * nk_local) / cfg.n_layers
            else:
                n_pairs = nq * nk
        pieces["block_rest"] = {
            k: (max(A[k] - Pp.get(k, 0.0), 0.0) if k != "coll_detail" else A[k])
            for k in A
        }
        pieces["attn_pair"] = Pp
        mults_extra = {"block_rest": L * nm * (seq / S0),
                       "attn_pair": L * nm * n_pairs}

        emb_shape = jax.eval_shape(
            lambda k: LYR.init_embed(k, cfg, dt), jax.random.PRNGKey(0))
        eshard = param_shardings(mesh, emb_shape)
        toks = jax.ShapeDtypeStruct((mb, seq), jnp.int32)

        def emb_loss_vjp(ep, tk):
            def f(ep_):
                h = LYR.embed(ep_, tk)
                logits = LYR.unembed(ep_, cfg, h[:, :-1]).astype(jnp.float32)
                lse = jax.nn.logsumexp(logits, -1)
                gold = jnp.take_along_axis(logits, tk[:, 1:, None], -1)[..., 0]
                return jnp.mean(lse - gold)
            l, vjp = jax.vjp(f, ep)
            return vjp(jnp.ones(()))

        pieces["embed_loss"] = piece_cost(
            emb_loss_vjp,
            (eshard, NamedSharding(mesh, logical_to_spec(mesh, ("dp", None), (mb, seq)))),
            (emb_shape, toks), mesh)
        mult_emb = nm

        ocfg = adamw.AdamWConfig()
        opt_shape = jax.eval_shape(partial(adamw.init, ocfg), params_shape)
        pshard = param_shardings(mesh, params_shape)
        oshard = adamw.OptState(
            step=NamedSharding(mesh, P()), m=pshard, v=pshard, master=())
        pieces["optimizer"] = piece_cost(
            lambda p, g, o: adamw.apply(ocfg, p, g, o)[0],
            (pshard, pshard, oshard), (params_shape, params_shape, opt_shape), mesh)
        mults = {"embed_loss": mult_emb, "optimizer": 1, **mults_extra}
        tokens = B * seq * (2 if cfg.is_encdec else 1)
        model_flops = 6 * active_p * tokens
    elif shape.mode == "prefill":
        seq = S // 2 if cfg.is_encdec else S
        S0 = min(512, seq)
        cfg0 = cfg.replace(q_chunk=S0, kv_chunk=S0)
        x0 = jax.ShapeDtypeStruct((B, S0, cfg.d_model), dt)
        pos0 = jnp.zeros((B, S0), jnp.int32)
        A = piece_cost(
            lambda p, xx: LM.block_train(p, cfg0, xx, pos0, win)[0],
            (lshard, xsharding(B, S0)), (layer_shape, x0), mesh)
        if cfg.kind == "rwkv":
            Pp = {k: 0.0 for k in ("flops", "bytes", "coll_bytes")}
            n_pairs = seq / S0
        else:
            N, Kh, dh = cfg.n_heads, cfg.kv_heads, cfg.head_dim
            qs = jax.ShapeDtypeStruct((B, S0, N, dh), dt)
            ks = jax.ShapeDtypeStruct((B, S0, Kh, dh), dt)
            qsh = NamedSharding(mesh, logical_to_spec(
                mesh, ("dp", None, "tp", None), (B, S0, N, dh)))
            ksh = NamedSharding(mesh, logical_to_spec(
                mesh, ("dp", None, "tp", None), (B, S0, Kh, dh)))
            Pp = piece_cost(
                lambda q, k, v: LYR._block_attn(q, k, v, pos0, pos0, True, None, S0, S0),
                (qsh, ksh, ksh), (qs, ks, ks), mesh)
            nq = seq / S0
            nk = seq / S0
            if cfg.window and cfg.global_layers:
                n_glob = len(cfg.global_layers)
                nk_local = min(nk, (cfg.window + S0) / S0 + 1)
                n_pairs = (n_glob * nq * nk
                           + (cfg.n_layers - n_glob) * nq * nk_local) / cfg.n_layers
            elif cfg.window:
                n_pairs = nq * min(nk, (cfg.window + S0) / S0 + 1)
            else:
                n_pairs = nq * nk
        pieces["block_rest"] = {
            k: (max(A[k] - Pp.get(k, 0.0), 0.0) if k != "coll_detail" else A[k])
            for k in A
        }
        pieces["attn_pair"] = Pp
        emb_shape = jax.eval_shape(
            lambda k: LYR.init_embed(k, cfg, dt), jax.random.PRNGKey(0))
        eshard = param_shardings(mesh, emb_shape)
        toks = jax.ShapeDtypeStruct((B, seq), jnp.int32)
        pieces["embed_loss"] = piece_cost(
            lambda ep, tk: LYR.unembed(ep, cfg, LYR.embed(ep, tk)[:, -1:]),
            (eshard, NamedSharding(mesh, logical_to_spec(mesh, ("dp", None), (B, seq)))),
            (emb_shape, toks), mesh)
        mults = {"block_rest": L * (seq / S0), "attn_pair": L * n_pairs,
                 "embed_loss": 1}
        model_flops = 2 * active_p * B * seq * (2 if cfg.is_encdec else 1)
    else:  # decode
        seq = S
        cache_full = jax.eval_shape(
            lambda: model.init_cache(B, seq, src_len=seq // 2 if cfg.is_encdec else 0))
        lc0 = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), cache_full["layers"]
        ) if model._uniform_cache else cache_full["layers"][0]
        lcshard = cache_shardings(mesh, {"layers": lc0})["layers"]
        x = jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)
        posv = jnp.full((B,), seq, jnp.int32)
        w0 = int(np.asarray(model._layer_windows())[0])

        def dec(p, lc, xx):
            return model._decode_block(p, xx, lc, posv, w0)[0]

        pieces["layer"] = piece_cost(
            dec, (lshard, lcshard,
                  NamedSharding(mesh, logical_to_spec(mesh, ("dp", None, None),
                                                      (B, 1, cfg.d_model)))),
            (layer_shape, lc0, x), mesh)
        emb_shape = jax.eval_shape(
            lambda k: LYR.init_embed(k, cfg, dt), jax.random.PRNGKey(0))
        eshard = param_shardings(mesh, emb_shape)
        toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pieces["embed_loss"] = piece_cost(
            lambda ep, tk: LYR.unembed(ep, cfg, LYR.embed(ep, tk)),
            (eshard, NamedSharding(mesh, logical_to_spec(mesh, ("dp", None), (B, 1)))),
            (emb_shape, toks), mesh)
        mults = {"layer": L, "embed_loss": 1}   # decode: no inner loops
        model_flops = 2 * active_p * B

    flops = sum(pieces[k]["flops"] * m for k, m in mults.items())
    bytes_ = sum(pieces[k]["bytes"] * m for k, m in mults.items())
    coll = sum(pieces[k]["coll_bytes"] * m for k, m in mults.items())
    t_c, t_m, t_l = flops / PEAK_FLOPS, bytes_ / HBM_BW, coll / LINK_BW
    bound = max((t_c, "compute"), (t_m, "memory"), (t_l, "collective"))
    ideal_t = model_flops / n_dev / PEAK_FLOPS
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "pieces": pieces, "multipliers": mults,
        "per_device": {"flops": flops, "hbm_bytes": bytes_, "coll_bytes": coll},
        "terms_s": {"compute": t_c, "memory": t_m, "collective": t_l},
        "bottleneck": bound[1],
        "model_flops": model_flops,
        "useful_ratio": model_flops / max(flops * n_dev, 1.0),
        "roofline_fraction": ideal_t / max(t_c, t_m, t_l),
        "params_total": total_p, "params_active": _count_params(cfg, params_shape)[1],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all cells on the single-pod mesh (§Roofline table)")
    ap.add_argument("--out", default="artifacts/roofline")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    cells = configs.all_cells() if args.all else [(args.arch, args.shape)]
    for arch, shape in cells:
        tag = f"{arch}__{shape}__{'2x16x16' if args.multi_pod else '16x16'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[skip] {tag}")
            continue
        try:
            rec = measure_cell(arch, shape, args.multi_pod)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            t = rec["terms_s"]
            print(f"[ok] {tag}: compute {t['compute']*1e3:.2f}ms  "
                  f"memory {t['memory']*1e3:.2f}ms  coll {t['collective']*1e3:.2f}ms"
                  f"  → {rec['bottleneck']}  frac={rec['roofline_fraction']:.2f}")
        except Exception as e:  # noqa: BLE001
            import traceback
            print(f"[FAIL] {tag}: {e}")
            with open(path + ".err", "w") as f:
                f.write(traceback.format_exc())


if __name__ == "__main__":
    main()
