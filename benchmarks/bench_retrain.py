"""Incremental retraining benchmark: maintained messages vs per-query SumProd.

  R1  Fresh-fit message reuse: training through the MaintainedEngine
      answers the SAME boosting queries (identical trees, checked) while
      emitting strictly fewer segment-⊕ messages than the per-query
      inside-out baseline — node-uniform tables' messages are cached
      across levels, trees, and query families.  Sweeping star width D,
      the direct baseline emits (D+fact−1) edges per family while the
      maintained path re-emits ~the grouping root's path, so the ratio
      grows with schema width (the asymptotic claim, mirroring the
      serving-side I1).
  R2  Delta-epoch retraining: after a concept-drift batch, a warm-start
      ``refit`` answers its delta-epoch of boosting queries with
      strictly fewer edge emissions than a from-scratch fit of the
      same-size model (frozen-tree messages on unchanged tables hit the
      cache), and the refit model's MSE on the live join matches the
      full-refit oracle within sketching tolerance.  Star / chain /
      snowflake shapes.

    PYTHONPATH=src python benchmarks/bench_retrain.py [--smoke]
"""
from __future__ import annotations

import argparse

import numpy as np
import jax.numpy as jnp

from _common import emit
from repro.core import BoostConfig, Booster, materialize_join, predict_rows
from repro.incremental import IncrementalBooster
from repro.relational.generators import (
    chain_schema, drift_stream, snowflake_schema, star_schema,
)

# full-refit parity band: the warm-started model keeps pre-drift trees
# and corrects them with fresh residual trees, so compare NORMALIZED
# quality — the MSE gap to the from-scratch oracle, as a fraction of the
# label variance, must stay within the sketching-tolerance band
PARITY_GAP = 0.05


def _mse(trees, eff):
    J = materialize_join(eff)
    X = jnp.stack([J[c] for (_, c) in eff.features], axis=1)
    y = np.asarray(J[eff.label_column])
    return (float(np.mean((y - np.asarray(predict_rows(trees, X))) ** 2)),
            float(np.var(y)))


def _trees_equal(a, b):
    return all(
        np.array_equal(np.asarray(x.feat), np.asarray(y.feat))
        and np.allclose(np.asarray(x.thr), np.asarray(y.thr))
        and np.allclose(np.asarray(x.leaf), np.asarray(y.leaf), atol=1e-4)
        for x, y in zip(a, b)
    )


def r1_fresh_fit_reuse(smoke: bool):
    rows = []
    n_fact = 200 if smoke else 800
    dims = [2, 4] if smoke else [2, 4, 8]
    for d in dims:
        sch = star_schema(seed=1, n_fact=n_fact, n_dim=16, n_dim_tables=d)
        cfg = BoostConfig(n_trees=2, depth=2, mode="sketch", ssr_mode="off")
        ib = IncrementalBooster(sch, cfg)
        trees_i, _ = ib.fit()
        direct = Booster(sch, cfg)
        trees_d, _ = direct.fit()
        assert _trees_equal(trees_i, trees_d), \
            "maintained engine must reproduce the direct engine's trees"
        e_i, e_d = ib.counter.edges, direct.counter.edges
        assert e_i < e_d, "maintained fit must emit fewer edges"
        rows.append({
            "bench": "R1", "schema": f"star(D={d})",
            "edges_maintained": e_i, "edges_per_query": e_d,
            "edge_ratio": round(e_d / e_i, 1),
            "cache_hit_rate": round(ib.engine.cache.hit_rate, 2),
            "trees_identical": True,
        })
    return rows


def r2_delta_epoch(smoke: bool):
    rows = []
    shapes = [
        ("star", star_schema(seed=2, n_fact=150 if smoke else 600, n_dim=12)),
        ("chain", chain_schema(seed=3, n_rows=80 if smoke else 300,
                               n_tables=3, fanout=2)),
        ("snowflake", snowflake_schema(seed=4, n_fact=100 if smoke else 400,
                                       n_dim=8, n_sub=4)),
    ]
    # enough drift epochs that the frozen prefix is a minority of the
    # warm-started ensemble — parity vs the from-scratch oracle needs
    # the corrective trees to dominate
    n_batches = 3 if smoke else 4
    for name, sch in shapes:
        cfg = BoostConfig(n_trees=2, depth=2, mode="sketch", ssr_mode="off")
        ib = IncrementalBooster(sch, cfg)
        ib.fit()
        inc_edges = inc_queries = 0
        for batch in drift_stream(sch, ib.live_rows, seed=5,
                                  n_batches=n_batches, rows_per_batch=4):
            rep = ib.refit(deltas=batch, n_new_trees=2, drift_threshold=0.0)
            inc_edges += rep.edges
            inc_queries += rep.queries
        # full-refit oracle: from-scratch fit of the same-size model on
        # the effective live tables, per drift batch
        eff = ib.effective_schema()
        full = Booster(eff, BoostConfig(
            n_trees=len(ib.trees), depth=cfg.depth, mode=cfg.mode,
            ssr_mode="off", seed=cfg.seed))
        trees_f, _ = full.fit()
        full_edges = full.counter.edges * n_batches
        full_queries = full.counter.count * n_batches
        assert inc_edges < full_edges, (
            f"{name}: delta-epoch refits must emit fewer edges than "
            f"refit-from-scratch ({inc_edges} vs {full_edges})")
        mse_i, var_y = _mse(ib.trees, eff)
        mse_f, _ = _mse(trees_f, eff)
        gap = (mse_i - mse_f) / max(var_y, 1e-9)
        assert gap <= PARITY_GAP, (
            f"{name}: refit quality must match full refit "
            f"(mse {mse_i:.3f} vs {mse_f:.3f}, gap {gap:.1%} of var)")
        rows.append({
            "bench": "R2", "schema": name, "drift_batches": n_batches,
            "edges_incremental": inc_edges, "edges_full_refit": full_edges,
            "edge_ratio": round(full_edges / inc_edges, 1),
            "queries_incremental": inc_queries,
            "queries_full_refit": full_queries,
            "mse_incremental": round(mse_i, 3),
            "mse_full_refit": round(mse_f, 3),
            "parity_gap_of_var": round(gap, 4),
            "var_y": round(var_y, 3),
            "cache_hit_rate": round(ib.engine.cache.hit_rate, 2),
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (interpret mode)")
    args = ap.parse_args(argv)
    rows = r1_fresh_fit_reuse(args.smoke) + r2_delta_epoch(args.smoke)
    for r in rows:
        print(r)
    widest = max((r for r in rows if r["bench"] == "R1"),
                 key=lambda r: r["edge_ratio"])
    assert widest["edge_ratio"] >= 2.0, widest
    print(f"maintained-message training on {widest['schema']}: "
          f"{widest['edge_ratio']}× fewer segment-⊕ emissions than "
          f"per-query SumProd (identical trees)")
    worst = min((r for r in rows if r["bench"] == "R2"),
                key=lambda r: r["edge_ratio"])
    print(f"delta-epoch refit: ≥{worst['edge_ratio']}× fewer emissions than "
          f"refit-from-scratch across shapes, MSE parity within sketching "
          f"tolerance")
    emit("retrain", rows, {
        "r1_edge_ratio_widest": widest["edge_ratio"],
        "r2_edge_ratio_worst": worst["edge_ratio"],
        "r2_parity_gap_worst": max(r["parity_gap_of_var"]
                                   for r in rows if r["bench"] == "R2"),
    }, config={"smoke": args.smoke})
    return rows


if __name__ == "__main__":
    main()
