"""Split-search benchmark: exact argsort sweep vs quantile-histogram sweep.

  S1  Sweep wall-time + split-gain parity on star/chain/snowflake with
      WIDE tables (d_t ≥ 8, n ≥ 4096): `best_split_for_table` is timed
      jitted on realistic node statistics, exact vs hist (B=256).  The
      histogram route must win wall-clock on every wide table — the
      O(n)-length prefix scan and per-row score evaluation collapse to
      O(B) — while the best split-gain stays within a few % of exact
      (the candidate set is a quantile subsample of the exact sweep's;
      the binned statistics themselves are exact per candidate).

  S2  Plan-maintenance cost per delta-epoch: exact `refresh_plans`
      rebuilds every table's float argsort wholesale (the cost ROADMAP
      called out for maintained retraining); hist consumes the engine's
      `plan_delta` and re-bins only delta-touched rows against frozen
      edges.  Reports ms/epoch and rows re-binned per epoch — o(n) for
      small deltas — and asserts the hist route is faster.

    PYTHONPATH=src python benchmarks/bench_splits.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from _common import emit
from repro.core import BoostConfig
from repro.core.hist import build_hist_plans
from repro.core.splits import best_split_for_table, build_split_plans
from repro.incremental import IncrementalBooster
from repro.relational.generators import (
    chain_schema, delta_stream, snowflake_schema, star_schema,
)

GAIN_GAP = 0.05          # hist top gain within 5% of the exact top gain
N_BINS = 256


def _wide_shapes(smoke: bool):
    n = 4096 if smoke else 16384
    return [
        ("star", star_schema(seed=1, n_fact=n, n_dim=64, n_dim_tables=2,
                             fact_feats=8), "fact"),
        ("chain", chain_schema(seed=2, n_rows=n, n_tables=3,
                               feats_per_table=8), "t0"),
        ("snowflake", snowflake_schema(seed=3, n_fact=n, n_dim=32, n_sub=8,
                                       fact_feats=8), "fact"),
    ]


def _node_stats(schema, table, K=8, seed=0):
    """Realistic level stats: Bernoulli membership counts and residual
    sums with real structure on feature 0 (so there IS a best split and
    gain parity is meaningful, not noise-on-noise)."""
    rng = np.random.default_rng(seed)
    fm = np.asarray(schema.featmat[table])
    rows = fm.shape[0]
    n = (rng.random((K, rows)) < 0.8).astype(np.float32)
    step = np.where(fm[:, 0] >= np.median(fm[:, 0]), 1.0, -1.0)
    s = (0.5 * step[None, :] + 0.3 * rng.standard_normal((K, rows))
         ).astype(np.float32) * n
    return jnp.asarray(n), jnp.asarray(s)


def _time(fn, *args, reps):
    out = fn(*args)
    jax.block_until_ready(out)                     # compile outside the clock
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3


def s1_sweep(smoke: bool):
    rows = []
    reps = 20 if smoke else 50
    for name, sch, table in _wide_shapes(smoke):
        pe = build_split_plans(sch)[table]
        ph = build_hist_plans(sch, n_bins=N_BINS)[table]
        n, s = _node_stats(sch, table)
        f_exact = jax.jit(lambda a, b: best_split_for_table(pe, a, b))
        f_hist = jax.jit(lambda a, b: best_split_for_table(ph, a, b))
        t_e = _time(f_exact, n, s, reps=reps)
        t_h = _time(f_hist, n, s, reps=reps)
        g_e = float(jnp.max(f_exact(n, s).score))
        g_h = float(jnp.max(f_hist(n, s).score))
        gap = (g_e - g_h) / max(abs(g_e), 1e-9)
        d_t, n_rows = pe.order.shape
        assert d_t >= 8 and n_rows >= 4096, (d_t, n_rows)
        # wall-clock ordering is enforced only in full runs: CI smoke on a
        # shared runner must not fail on scheduling noise (the other CI
        # benchmarks gate on counted work / parity for the same reason)
        if not smoke:
            assert t_h < t_e, (
                f"{name}: hist sweep must beat exact on wide tables "
                f"({t_h:.2f}ms vs {t_e:.2f}ms)")
        assert gap <= GAIN_GAP, (
            f"{name}: top hist gain must track exact ({g_h} vs {g_e})")
        rows.append({
            "bench": "S1", "schema": name, "table": table,
            "rows": n_rows, "d_t": d_t, "K": int(n.shape[0]),
            "exact_ms": round(t_e, 2), "hist_ms": round(t_h, 2),
            "speedup": round(t_e / t_h, 1), "gain_gap": round(gap, 4),
        })
    return rows


def s2_plan_maintenance(smoke: bool):
    rows = []
    n_fact = 8192 if smoke else 32768
    n_epochs = 4 if smoke else 8
    sch = star_schema(seed=4, n_fact=n_fact, n_dim=64, n_dim_tables=2,
                      fact_feats=8)
    results = {}
    for mode, extra in [("exact", {}),
                        ("hist", dict(split_mode="hist", hist_bins=N_BINS))]:
        cfg = BoostConfig(n_trees=1, depth=2, mode="sketch", ssr_mode="off",
                          **extra)
        ib = IncrementalBooster(sch, cfg)
        ib.fit()
        total_ms = 0.0
        for batch in delta_stream(sch, ib.live_rows, seed=5,
                                  n_batches=n_epochs, ops_per_batch=6):
            ib.apply(batch)
            t0 = time.perf_counter()
            ib.booster.refresh_plans()
            total_ms += (time.perf_counter() - t0) * 1e3
        n_total = sum(ib.state.capacity(t.name) for t in sch.tables)
        # re-bin work the maintenance path ACTUALLY performed (the
        # plans' own drift meters, 0 in exact mode) — not the bench's
        # input op count, so a regression to full re-binning fails here
        rebinned = sum(getattr(p, "rebinned_since_edges", 0)
                       for p in ib.booster.plans.values())
        results[mode] = (total_ms / n_epochs, rebinned / n_epochs, n_total)
    exact_ms, _, n_total = results["exact"]
    hist_ms, rows_per_epoch, _ = results["hist"]
    if not smoke:                        # timing gate: full runs only
        assert hist_ms < exact_ms, (
            f"incremental re-bin must beat argsort rebuild "
            f"({hist_ms:.2f}ms vs {exact_ms:.2f}ms per epoch)")
    assert 0 < rows_per_epoch < 0.05 * n_total, (
        "per-epoch re-bin work must be o(n) and incremental (an edge "
        "rebuild or full re-bin would show here)", rows_per_epoch, n_total)
    rows.append({
        "bench": "S2", "schema": f"star(n_fact={n_fact})",
        "epochs": n_epochs,
        "argsort_rebuild_ms_per_epoch": round(exact_ms, 2),
        "incremental_rebin_ms_per_epoch": round(hist_ms, 2),
        "speedup": round(exact_ms / hist_ms, 1),
        "rows_rebinned_per_epoch": round(rows_per_epoch, 1),
        "store_rows_total": n_total,
    })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI")
    args = ap.parse_args(argv)
    rows = s1_sweep(args.smoke) + s2_plan_maintenance(args.smoke)
    for r in rows:
        print(r)
    worst = min((r for r in rows if r["bench"] == "S1"),
                key=lambda r: r["speedup"])
    print(f"histogram sweep: ≥{worst['speedup']}× faster than the exact "
          f"sweep on wide tables (gain gap ≤ {GAIN_GAP:.0%})")
    s2 = next(r for r in rows if r["bench"] == "S2")
    print(f"plan maintenance: {s2['speedup']}× faster per delta-epoch, "
          f"re-binning {s2['rows_rebinned_per_epoch']} of "
          f"{s2['store_rows_total']} rows")
    emit("splits", rows, {
        "s1_gain_gap_worst": max(r["gain_gap"]
                                 for r in rows if r["bench"] == "S1"),
        "s2_rebin_frac": s2["rows_rebinned_per_epoch"]
        / max(s2["store_rows_total"], 1),
        "s2_speedup": s2["speedup"],
    }, config={"smoke": args.smoke})
    return rows


if __name__ == "__main__":
    main()
