"""Generate the EXPERIMENTS.md §Roofline table from artifacts.

Reports BOTH memory accountings per cell:
  mem_hlo   — spec-defined HLO bytes of the jnp implementation (includes
              the dense (S0×S0) f32 score traffic of every attention
              block pair);
  mem_fused — the TPU-target estimate: the attention pair charged its
              analytic HBM IO only (q/k/v/out + grads), since
              kernels/flash_attention keeps scores/probabilities in VMEM.
Bottleneck/fraction are judged on the fused accounting (the deployed
configuration); the HLO number is retained as the conservative bound.
"""
import glob
import json
import os
import sys

sys.path.insert(0, "src")

PEAK_FLOPS, HBM_BW, LINK_BW = 197e12, 819e9, 50e9


def fused_pair_bytes(cfg, mb_or_b, dp=16, S0=512, train=True):
    N, Kh, dh = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    passes = 3.0 if train else 1.5     # fwd+bwd re-reads vs fwd only
    io = passes * (2 * S0 * N * dh + 2 * S0 * Kh * dh) * 2.0
    shard = max(mb_or_b // dp, 1) / max(mb_or_b, 1)
    tp_shard = 1 / 16 if N % 16 == 0 else 1.0
    return io * mb_or_b * shard * tp_shard


def load_cell(path):
    r = json.load(open(path))
    from repro import configs

    cfg = configs.get(r["arch"])
    mults = r["multipliers"]
    flops = sum(r["pieces"][k]["flops"] * m for k, m in mults.items())
    coll = sum(r["pieces"][k]["coll_bytes"] * m for k, m in mults.items())
    mem_hlo = sum(r["pieces"][k]["bytes"] * m for k, m in mults.items())
    mem_fused = mem_hlo
    if "attn_pair" in r["pieces"]:
        shape = r["shape"]
        train = shape.startswith("train")
        mb = {"train_4k": 256 // max(1, round(mults.get("embed_loss", 1))),
              }.get(shape, 32 if "prefill" in shape else 128)
        pair_f = fused_pair_bytes(cfg, mb, train=train)
        mem_fused = mem_hlo - r["pieces"]["attn_pair"]["bytes"] * mults["attn_pair"] \
            + pair_f * mults["attn_pair"]
    t = {
        "compute": flops / PEAK_FLOPS,
        "mem_hlo": mem_hlo / HBM_BW,
        "mem_fused": max(mem_fused, flops * 0.0) / HBM_BW,
        "coll": coll / LINK_BW,
    }
    ideal = r["model_flops"] / 256 / PEAK_FLOPS
    bound = max(t["compute"], t["mem_fused"], t["coll"])
    dom = ("compute" if bound == t["compute"] else
           "memory" if bound == t["mem_fused"] else "collective")
    return {
        "arch": r["arch"], "shape": r["shape"],
        **{k: round(v, 3) for k, v in t.items()},
        "bottleneck": dom,
        "fraction": round(ideal / bound, 4),
        "useful_ratio": round(r["useful_ratio"], 3),
        "model_flops": r["model_flops"],
    }


def main():
    rows = [load_cell(p) for p in sorted(glob.glob("artifacts/roofline/*.json"))]
    hdr = ("arch", "shape", "compute", "mem_fused", "mem_hlo", "coll",
           "bottleneck", "fraction", "useful_ratio")
    print("| " + " | ".join(hdr) + " |")
    print("|" + "---|" * len(hdr))
    for r in rows:
        print("| " + " | ".join(str(r[h]) for h in hdr) + " |")
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/roofline_table.json", "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
