"""BENCH_*.json summary + CI gate, plus the EXPERIMENTS.md §Roofline table.

Default mode reads every ``BENCH_<name>.json`` in the bench dir (repo
root unless ``--dir``/``REPRO_BENCH_DIR``) and prints the headline
metrics per benchmark — the committed perf trajectory at a glance.

``--check`` turns that into a gate for the nightly job.  It fails if

  * a benchmark pinned in ``benchmarks/baselines.json`` has no BENCH
    file,
  * a BENCH file fails schema validation (``repro.obs.validate_bench``),
  * a pinned metric regresses by more than 2× against its baseline:
    ``min`` pins fail when value < baseline/2, ``max`` pins fail when
    value > baseline*2.  The loose factor keeps count-derived ratios
    honest without tripping on run-to-run noise.

Baselines format (``benchmarks/baselines.json``)::

    {"serving": {"eval_ratio": {"pin": 13.0, "kind": "min"}}, ...}

``--roofline`` preserves the original report: the EXPERIMENTS.md
§Roofline table from ``artifacts/roofline/*.json``, with both memory
accountings per cell (mem_hlo = spec-defined HLO bytes; mem_fused = the
TPU-target estimate with flash-attention pairs charged analytic HBM IO
only).  Bottleneck/fraction are judged on the fused accounting.
"""
import argparse
import glob
import json
import os
import sys

sys.path.insert(0, "src")

PEAK_FLOPS, HBM_BW, LINK_BW = 197e12, 819e9, 50e9

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "baselines.json")


# ------------------------------------------------------------------ bench

def _bench_dir(arg):
    return arg or os.environ.get("REPRO_BENCH_DIR") or REPO_ROOT


def load_benches(bench_dir):
    """{name: (doc|None, [errors])} for every BENCH_*.json present."""
    from repro.obs import validate_bench
    out = {}
    for p in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        name = os.path.basename(p)[len("BENCH_"):-len(".json")]
        try:
            doc = json.load(open(p))
        except (OSError, ValueError) as e:
            out[name] = (None, [f"unreadable: {e}"])
            continue
        out[name] = (doc, validate_bench(doc))
    return out


def summarize(benches):
    for name, (doc, errs) in sorted(benches.items()):
        if errs:
            print(f"BENCH_{name}: INVALID — {'; '.join(errs)}")
            continue
        metrics = ", ".join(f"{k}={v}" for k, v in
                            sorted(doc.get("metrics", {}).items()))
        print(f"BENCH_{name}: {len(doc.get('rows', []))} rows  [{metrics}]")


def check(benches, baselines_path):
    """Return a list of failure strings (empty = gate passes)."""
    failures = []
    try:
        baselines = json.load(open(baselines_path))
    except OSError:
        return [f"baselines file missing: {baselines_path}"]
    for bench, pins in sorted(baselines.items()):
        if bench not in benches:
            failures.append(f"{bench}: BENCH_{bench}.json missing")
            continue
        doc, errs = benches[bench]
        if errs:
            failures.extend(f"{bench}: schema — {e}" for e in errs)
            continue
        metrics = doc.get("metrics", {})
        for metric, pin in sorted(pins.items()):
            if metric not in metrics:
                failures.append(f"{bench}.{metric}: metric missing")
                continue
            val, base, kind = metrics[metric], pin["pin"], pin["kind"]
            if kind == "min" and val < base / 2:
                failures.append(
                    f"{bench}.{metric}: {val} < baseline {base}/2 "
                    f"(>2× regression on a floor metric)")
            elif kind == "max" and val > base * 2:
                failures.append(
                    f"{bench}.{metric}: {val} > baseline {base}×2 "
                    f"(>2× regression on a ceiling metric)")
    # schema-invalid files that aren't pinned still fail the gate: a
    # benchmark that silently stops validating is itself a regression
    for name, (_, errs) in sorted(benches.items()):
        if errs and name not in baselines:
            failures.extend(f"{name}: schema — {e}" for e in errs)
    return failures


# --------------------------------------------------------------- roofline

def fused_pair_bytes(cfg, mb_or_b, dp=16, S0=512, train=True):
    N, Kh, dh = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    passes = 3.0 if train else 1.5     # fwd+bwd re-reads vs fwd only
    io = passes * (2 * S0 * N * dh + 2 * S0 * Kh * dh) * 2.0
    shard = max(mb_or_b // dp, 1) / max(mb_or_b, 1)
    tp_shard = 1 / 16 if N % 16 == 0 else 1.0
    return io * mb_or_b * shard * tp_shard


def load_cell(path):
    r = json.load(open(path))
    from repro import configs

    cfg = configs.get(r["arch"])
    mults = r["multipliers"]
    flops = sum(r["pieces"][k]["flops"] * m for k, m in mults.items())
    coll = sum(r["pieces"][k]["coll_bytes"] * m for k, m in mults.items())
    mem_hlo = sum(r["pieces"][k]["bytes"] * m for k, m in mults.items())
    mem_fused = mem_hlo
    if "attn_pair" in r["pieces"]:
        shape = r["shape"]
        train = shape.startswith("train")
        mb = {"train_4k": 256 // max(1, round(mults.get("embed_loss", 1))),
              }.get(shape, 32 if "prefill" in shape else 128)
        pair_f = fused_pair_bytes(cfg, mb, train=train)
        mem_fused = mem_hlo - r["pieces"]["attn_pair"]["bytes"] * mults["attn_pair"] \
            + pair_f * mults["attn_pair"]
    t = {
        "compute": flops / PEAK_FLOPS,
        "mem_hlo": mem_hlo / HBM_BW,
        "mem_fused": max(mem_fused, flops * 0.0) / HBM_BW,
        "coll": coll / LINK_BW,
    }
    ideal = r["model_flops"] / 256 / PEAK_FLOPS
    bound = max(t["compute"], t["mem_fused"], t["coll"])
    dom = ("compute" if bound == t["compute"] else
           "memory" if bound == t["mem_fused"] else "collective")
    return {
        "arch": r["arch"], "shape": r["shape"],
        **{k: round(v, 3) for k, v in t.items()},
        "bottleneck": dom,
        "fraction": round(ideal / bound, 4),
        "useful_ratio": round(r["useful_ratio"], 3),
        "model_flops": r["model_flops"],
    }


def roofline_main():
    rows = [load_cell(p) for p in sorted(glob.glob("artifacts/roofline/*.json"))]
    hdr = ("arch", "shape", "compute", "mem_fused", "mem_hlo", "coll",
           "bottleneck", "fraction", "useful_ratio")
    print("| " + " | ".join(hdr) + " |")
    print("|" + "---|" * len(hdr))
    for r in rows:
        print("| " + " | ".join(str(r[h]) for h in hdr) + " |")
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/roofline_table.json", "w") as f:
        json.dump(rows, f, indent=1)


# ------------------------------------------------------------------- main

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="gate: fail on missing/invalid/regressed BENCH files")
    ap.add_argument("--dir", default=None,
                    help="bench dir (default: repo root or REPRO_BENCH_DIR)")
    ap.add_argument("--baselines", default=BASELINES)
    ap.add_argument("--roofline", action="store_true",
                    help="emit the EXPERIMENTS.md roofline table instead")
    args = ap.parse_args(argv)
    if args.roofline:
        roofline_main()
        return 0
    benches = load_benches(_bench_dir(args.dir))
    summarize(benches)
    if not args.check:
        return 0
    failures = check(benches, args.baselines)
    if failures:
        print(f"\nbench check FAILED ({len(failures)}):")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nbench check OK: all pinned metrics within 2× of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
