"""Async, sharded, elastic checkpointing.

Layout:  <dir>/step_<N>/
            manifest.json        tree structure, shapes, dtypes, step
            <leaf-id>.npy        one file per leaf (global array)
         <dir>/LATEST            atomic pointer (written last)

Restore reshards on load: arrays are materialized per-device with
``jax.make_array_from_callback`` against the *target* sharding, so a
checkpoint written on one mesh restores onto any other (elastic
downscale/upscale after node failure — tested in tests/test_checkpoint.py
across different device counts).

Writes are asynchronous: device→host transfer happens at ``save`` call
time (consistent snapshot), file IO on a background thread; ``wait()``
joins.  A crash mid-write never corrupts the pointer (tmp dir + rename,
LATEST written after fsync-ordered completion).

Single-process note: leaves are written as full global arrays (all
shards addressable here).  On a real multi-host pod each host would
write only its addressable shards with per-shard index metadata — the
manifest format already carries the global shape/dtype needed for that
extension.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _to_storable(a: np.ndarray) -> np.ndarray:
    """numpy can't serialize ml_dtypes — store raw bits."""
    name = str(a.dtype)
    return a.view(_BITCAST[name]) if name in _BITCAST else a


def _from_storable(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _BITCAST:
        return a.view(getattr(ml_dtypes, dtype_name))
    return a


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---------------------------------------------------------------- save --
    def save(self, step: int, tree: Any, blocking: bool = False):
        """Snapshot now, write async (unless blocking)."""
        self.wait()
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]     # device→host now
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
        }

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for i, l in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"leaf_{i}.npy"), _to_storable(l))
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            with open(os.path.join(self.dir, ".LATEST_tmp"), "w") as f:
                f.write(str(step))
            os.replace(os.path.join(self.dir, ".LATEST_tmp"),
                       os.path.join(self.dir, "LATEST"))
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ------------------------------------------------------------- restore --
    def all_steps(self):
        return [
            int(d.split("_", 1)[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_")
        ]

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip())

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Load step into the structure of `like`, resharding onto
        `shardings` (a matching pytree of NamedSharding) if given."""
        self.wait()
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = _flatten(like)
        assert manifest["n_leaves"] == len(leaves), "tree structure changed"
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
            else [None] * len(leaves)
        )
        out = []
        for i, (l, sh) in enumerate(zip(leaves, shard_leaves)):
            arr = _from_storable(
                np.load(os.path.join(d, f"leaf_{i}.npy"), mmap_mode="r"),
                manifest["dtypes"][i],
            )
            want_dtype = l.dtype if hasattr(l, "dtype") else arr.dtype
            if sh is None:
                out.append(jax.numpy.asarray(np.asarray(arr), dtype=want_dtype))
            else:
                out.append(
                    jax.make_array_from_callback(
                        tuple(arr.shape), sh,
                        lambda idx, a=arr, dt=want_dtype: np.asarray(a[idx]).astype(dt),
                    )
                )
        return jax.tree_util.tree_unflatten(treedef, out)
