"""Batched scoring entry points over a :class:`CompiledEnsemble`.

Three traffic shapes (all jitted under the hood):

- :func:`score_grouped`  — bulk: (Σŷ, count) for EVERY row of a table in
  one SumProd pass (replaces the body of ``Booster.predict_grouped``).
- :func:`score_rows`     — interactive: a batch of row ids of a table;
  tables are static per model version, so this is a gather into the
  memoized bulk pass (the micro-batching service's hot path).
- :func:`score_fresh`    — rows that never touched the database: raw
  feature dicts routed through the materialized-path ``predict_rows``.

:func:`score_grouped_reference` preserves the seed per-leaf-per-tree
loop (with analytic query accounting) as the benchmark/test baseline.

Sharding: every entry point re-enters the ensemble's captured data mesh
(`distributed.spmd`), so the bulk pass runs row-sharded for mesh-compiled
ensembles while the outputs (and therefore the gathers `score_rows`
serves from) are replicated — callers see identical arrays either way.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.schema import Schema
from ..core.semiring import Arithmetic
from ..core.sumprod import QueryCounter, SumProd
from ..core.tree import TreeArrays, all_tables_leaf_masks, predict_rows
from ..distributed import spmd
from .compile import CompiledEnsemble


def _mesh_of(ens) -> Optional[object]:
    """Data mesh an ensemble-like object was built under (duck-typed:
    CompiledEnsemble, MaintainedScorer and StackedEnsembles all carry
    ``mesh``; anything without one is single-device)."""
    return getattr(ens, "mesh", None)


def score_grouped(ens: CompiledEnsemble, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row-of-``group_by`` (Σ ŷ(x), count) over x ∈ ρ⋈J — one pass."""
    with spmd.use_data_mesh(_mesh_of(ens)):
        return ens.score_grouped(group_by)


@jax.jit
def _gather(tot, cnt, ids):
    return jnp.take(tot, ids), jnp.take(cnt, ids)


def score_rows(ens: CompiledEnsemble, group_by: str, row_ids) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(Σŷ, count) for a batch of row ids of ``group_by``.

    Ids are validated host-side: jnp's out-of-bounds gather clamps, which
    would silently answer a lookup for a nonexistent row with another
    row's score — a serving API must reject it instead."""
    ids = np.asarray(row_ids, np.int64)
    n = ens.n_rows(group_by)
    if ids.size and (ids.min() < 0 or ids.max() >= n):
        bad = ids[(ids < 0) | (ids >= n)][:5]
        raise IndexError(
            f"row ids out of range for table {group_by!r} (n_rows={n}): {bad.tolist()}"
        )
    with spmd.use_data_mesh(_mesh_of(ens)):
        tot, cnt = ens.grouped_cached(group_by)
    return _gather(tot, cnt, jnp.asarray(ids, jnp.int32))


def score_mean_rows(ens: CompiledEnsemble, group_by: str, row_ids) -> jnp.ndarray:
    """Mean prediction per row id (Σŷ / count, 0 for rows outside the join)."""
    tot, cnt = score_rows(ens, group_by, row_ids)
    return tot / jnp.maximum(cnt, 1.0)


def score_fresh(ens: CompiledEnsemble, features: Dict[str, np.ndarray]) -> jnp.ndarray:
    """Score rows arriving with raw feature dicts (never stored in tables).

    ``features`` maps feature-column name → (batch,) values; every feature
    the schema exposes must be present (global feature order is taken from
    the schema).  Routed through the materialized-path ``predict_rows``.
    """
    sch = ens.schema
    cols = []
    for (_, c) in sch.features:
        if c not in features:
            raise KeyError(f"score_fresh: missing feature column {c!r}")
        cols.append(np.asarray(features[c], np.float32))
    X = jnp.asarray(np.stack(cols, axis=1))
    return predict_rows(ens.trees, X)


def score_grouped_reference(
    schema: Schema,
    trees: List[TreeArrays],
    group_by: str,
    counter: Optional[QueryCounter] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The seed scoring loop: one Arithmetic SumProd pass per leaf per
    tree + one count pass.  Kept verbatim as the old-vs-new baseline;
    queries are accounted analytically (n_trees·L + 1 — the jit trace
    would undercount the ``fori_loop`` body)."""
    ar = Arithmetic()
    sp = SumProd(schema)
    tot = jnp.zeros((schema.table(group_by).n_rows,), jnp.float32)
    for t in trees:
        lm = all_tables_leaf_masks(schema, t)

        def body(a, acc, lm=lm, t=t):
            f = {
                tn: ar.mask(jnp.ones((schema.table(tn).n_rows,)), lm[tn][a])
                for tn in lm
            }
            return acc + t.leaf[a] * sp(ar, f, group_by=group_by)

        tot = jax.lax.fori_loop(0, t.leaf.shape[0], body, tot)
    cnt = sp(ar, sp.ones_factors(ar), group_by=group_by)
    if counter is not None:
        counter.bump(sum(int(t.leaf.shape[0]) for t in trees) + 1)
    return tot, cnt
