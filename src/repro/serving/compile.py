"""Compile a trained ensemble into a one-pass relational scorer.

The seed scoring path (``Booster.predict_grouped``) walks tree × leaf
inside a ``fori_loop`` and issues one Arithmetic SumProd pass per leaf
per tree — O(n_trees · L) sequential inside-out passes per request.
Serving inverts that: compilation stacks **every leaf of every tree**
into one channel axis.

For each table T_t the per-leaf membership masks (L, n_rows) of all
trees concatenate into a single (total_leaves, n_rows) array; its
transpose, cast to f32, is T_t's factor in a ``Channels(total_leaves)``
product semiring.  ONE inside-out pass grouped by ρ's table then yields

    counts[ρ, a] = |{x ∈ ρ ⋈ J : x in leaf a}|        (all a at once)

and the served quantities are two dense contractions:

    Σŷ[ρ]  = counts[ρ, :] @ leaf_values                 (boosted sum)
    |ρ⋈J|  = Σ_{a ∈ leaves of tree 0} counts[ρ, a]      (any one tree
              partitions J, so its leaf counts sum to the group size)

SumProd evaluations per request drop from n_trees·L + 1 to **1**; the
wide segment-⊕ that remains is a dense (n_rows, total_leaves) segment
sum — optionally routed through the Pallas one-hot-matmul kernel
(`kernels/segment_sum`, same MXU reformulation as `count_sketch`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.schema import Schema
from ..core.semiring import Channels
from ..core.sumprod import QueryCounter, SumProd
from ..core.tree import TreeArrays, leaf_masks
from ..distributed import spmd


@dataclasses.dataclass(frozen=True)
class KernelChannels(Channels):
    """Channels semiring whose segment-⊕ runs on the Pallas kernel.

    Under an active multi-device data mesh the Pallas route falls back to
    the dense ``segment_sum`` — `pallas_call` is a single-device program
    and would force an all-gather of the row-sharded factor; the XLA
    scatter path partitions cleanly instead."""

    interpret: bool = True

    def segment_add(self, vals, segment_ids, num_segments):
        from ..kernels.segment_sum.ops import segment_sum_op

        if (vals.ndim == 2 and vals.dtype == jnp.float32
                and spmd.data_axis_size() <= 1):
            return segment_sum_op(vals, segment_ids, num_segments,
                                  interpret=self.interpret)
        return super().segment_add(vals, segment_ids, num_segments)


def stack_table_factor(
    schema: Schema,
    trees: List[TreeArrays],
    table: str,
    featmat: Optional[jnp.ndarray] = None,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """Stacked leaf-mask factor for one table: (n_rows, total_leaves).

    With ``featmat`` (k, d_t), only those k feature rows are evaluated —
    the per-row factor slice incremental maintenance scatters back into a
    live factor after a delta."""
    per_tree = [leaf_masks(schema, table, t, featmat=featmat) for t in trees]
    return jnp.concatenate(per_tree, axis=0).T.astype(dtype)


@dataclasses.dataclass
class CompiledEnsemble:
    """A trained ensemble lowered to single-pass relational scoring.

    factors: per-table (n_rows, total_leaves) — stacked leaf masks, ready
    to drop into a Channels(total_leaves) SumProd query.  ``factor_dtype``
    selects their storage dtype: f32 (exact counts) or bf16 (masks are
    0/1, so bf16 halves factor memory at a small count error bounded by
    the 8-bit mantissa — served totals stay within benchmark tolerance).

    ``data_version`` is bumped by whoever mutates served state in place
    (incremental/maintain.py) — caches keyed on it can never serve stale
    scores after a delta.

    ``mesh``: data mesh captured at compile time (ambient
    `spmd.current_data_mesh()` by default).  Factors are placed
    row-sharded over its data axis and flow as jit *arguments*, so the
    sharding sticks; leaf values replicate; the SumProd message
    emissions inside the pass are the collective point (`psum_message`),
    so grouped outputs come back replicated and bit-equal to
    single-device (0/1 leaf-mask counts are integer-exact under the
    cross-shard re-association).  ``mesh=None`` is the plain
    single-device program.
    """

    schema: Schema
    trees: List[TreeArrays]
    leaf_values: jnp.ndarray               # (total_leaves,)
    factors: Dict[str, jnp.ndarray]        # table → (n_rows, total_leaves)
    tree0_leaves: int                      # leaves of tree 0 (for counts)
    use_kernel: bool = False
    counter: Optional[QueryCounter] = None
    factor_dtype: "jnp.dtype" = jnp.float32
    data_version: int = 0
    mesh: Optional[object] = None          # jax.sharding.Mesh | None

    def __post_init__(self):
        self._sp = SumProd(self.schema)
        self._sem = (
            KernelChannels(self.total_leaves, self.factor_dtype)
            if self.use_kernel else Channels(self.total_leaves, self.factor_dtype)
        )
        self._score_fns: Dict[str, callable] = {}
        self._grouped: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]] = {}
        if spmd.data_axis_size(self.mesh) > 1:
            self.factors = spmd.shard_factors(self.factors, self.mesh)
            self.leaf_values = spmd.replicate_put(self.leaf_values, self.mesh)

    def device_count(self) -> int:
        """Data-axis width this ensemble is sharded over (1 = unsharded)."""
        return spmd.data_axis_size(self.mesh)

    @property
    def total_leaves(self) -> int:
        return int(self.leaf_values.shape[0])

    @property
    def n_trees(self) -> int:
        return len(self.trees)

    def n_rows(self, table: str) -> int:
        """Row-id domain of ``table``'s factor (== schema n_rows here;
        capacity for maintained scorers — keeps id validation duck-typed)."""
        return int(self.factors[table].shape[0])

    # ----------------------------------------------------------- scoring --
    def _score_fn(self, group_by: str):
        """Jitted one-pass scorer for one grouping table (compile-once)."""
        if group_by not in self._score_fns:
            sp, sem, L0 = self._sp, self._sem, self.tree0_leaves

            mesh = self.mesh

            @jax.jit
            def run(factors, vals):
                counts = sp(sem, factors, group_by=group_by)   # (n_g, A)
                # contract over the (never-sharded) leaf axis as an
                # explicitly sequenced FMA chain: each output row reads
                # only its own counts row, so row sharding cannot move
                # the bits — unlike a gemv, whose A-contraction blocking
                # varies with the local row count.  The rows therefore
                # stay sharded through the whole pass; only the two
                # (n_g,) results are gathered back.
                tot = counts[:, 0] * vals[0]
                for j in range(1, int(vals.shape[0])):
                    tot = tot + counts[:, j] * vals[j]
                # integer-valued counts: the cnt reduction is exact in
                # f32 in any association order
                cnt = jnp.sum(counts[:, :L0], axis=1)
                return (spmd.replicate(tot.astype(jnp.float32), mesh),
                        spmd.replicate(cnt.astype(jnp.float32), mesh))

            self._score_fns[group_by] = run
        return self._score_fns[group_by]

    def score_grouped(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(Σŷ, |ρ⋈J|) per row of ``group_by`` — ONE SumProd evaluation."""
        if self.counter is not None:
            self.counter.bump(1)
        # trace (first call) must see this ensemble's mesh — psum_message
        # inside the pass reads the ambient context at trace time
        with spmd.use_data_mesh(self.mesh):
            return self._score_fn(group_by)(self.factors, self.leaf_values)

    def grouped_cached(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Memoized full-table scores: tables are static per model version,
        so interactive row lookups reduce to gathers into this pass."""
        if group_by not in self._grouped:
            self._grouped[group_by] = self.score_grouped(group_by)
        return self._grouped[group_by]


def compile_ensemble(
    schema: Schema,
    trees: List[TreeArrays],
    use_kernel: bool = False,
    counter: Optional[QueryCounter] = None,
    factor_dtype=jnp.float32,
    mesh=None,
) -> CompiledEnsemble:
    """Stack per-table leaf masks across all trees into channel factors.

    ``mesh``: explicit data mesh, or None to capture the ambient
    `spmd.use_data_mesh` context (still None outside any context —
    the plain single-device program)."""
    if not trees:
        raise ValueError("cannot compile an empty ensemble")
    factors = {
        t.name: stack_table_factor(schema, trees, t.name, dtype=factor_dtype)
        for t in schema.tables
    }
    leaf_values = jnp.concatenate([t.leaf for t in trees]).astype(jnp.float32)
    return CompiledEnsemble(
        schema=schema,
        trees=list(trees),
        leaf_values=leaf_values,
        factors=factors,
        tree0_leaves=int(trees[0].leaf.shape[0]),
        use_kernel=use_kernel,
        counter=counter,
        factor_dtype=factor_dtype,
        mesh=mesh if mesh is not None else spmd.current_data_mesh(),
    )
