"""Batched multi-model scoring: A/B variants in one SumProd pass.

Compiled ensembles over the same schema differ only along the leaf
channel axis, so N variants stack into ONE factor set: per table the
(n_rows, A_m) factors concatenate to (n_rows, ΣA_m), one inside-out
pass yields every model's leaf counts at once, and the contraction
splits per model by slicing the channel axis — N models for the query
cost of one (the registry's A/B traffic no longer multiplies SumProd
evaluations by the number of live variants).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.semiring import Channels
from ..core.sumprod import QueryCounter, SumProd
from .compile import CompiledEnsemble


@dataclasses.dataclass
class StackedEnsembles:
    """N compiled ensembles fused along the leaf channel axis."""

    ensembles: List[CompiledEnsemble]
    factors: Dict[str, jnp.ndarray]        # table → (n_rows, ΣA_m)
    leaf_values: jnp.ndarray               # (ΣA_m,)
    offsets: List[int]                     # model m spans [off[m], off[m+1])
    counter: Optional[QueryCounter] = None

    def __post_init__(self):
        self.schema = self.ensembles[0].schema
        # pin the constituents' data_versions at stack time: the stacked
        # factor set is immutable, and scores computed from it belong to
        # exactly these versions even if a constituent MaintainedScorer-
        # derived ensemble is later replaced under the same registry slot
        self.data_versions = tuple(
            getattr(e, "data_version", 0) for e in self.ensembles)
        self._sp = SumProd(self.schema)
        self._sem = Channels(int(self.leaf_values.shape[0]),
                             self.factors[self.schema.names[0]].dtype)
        self._score_fns: Dict[str, callable] = {}

    @property
    def n_models(self) -> int:
        return len(self.ensembles)

    def _score_fn(self, group_by: str):
        if group_by not in self._score_fns:
            sp, sem = self._sp, self._sem
            spans = [(self.offsets[m], self.offsets[m + 1],
                      self.ensembles[m].tree0_leaves)
                     for m in range(self.n_models)]

            @jax.jit
            def run(factors, vals):
                counts = sp(sem, factors, group_by=group_by)   # (n_g, ΣA)
                out = []
                for (lo, hi, l0) in spans:
                    c = counts[:, lo:hi]
                    out.append((
                        (c @ vals[lo:hi]).astype(jnp.float32),
                        jnp.sum(c[:, :l0], axis=1).astype(jnp.float32),
                    ))
                return out

            self._score_fns[group_by] = run
        return self._score_fns[group_by]

    def score_grouped(self, group_by: str) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
        """Per-model [(Σŷ, |ρ⋈J|)] for every row of ``group_by`` — ONE
        SumProd evaluation for all N models."""
        if self.counter is not None:
            self.counter.bump(1)
        return self._score_fn(group_by)(self.factors, self.leaf_values)


def stack_ensembles(
    ensembles: List[CompiledEnsemble],
    counter: Optional[QueryCounter] = None,
) -> StackedEnsembles:
    """Concatenate N same-schema ensembles' leaf axes into one factor set."""
    if not ensembles:
        raise ValueError("need at least one ensemble to stack")
    sch = ensembles[0].schema
    for e in ensembles:
        # a MaintainedScorer's capacity-padded factors and dynamic key
        # dictionaries don't fit the static join tree this pass uses —
        # stack a static snapshot (compile_ensemble over its effective
        # tables) instead
        bad = [t.name for t in e.schema.tables
               if e.factors[t.name].shape[0] != t.n_rows]
        if bad:
            raise ValueError(
                f"cannot stack a maintained/padded scorer (factor rows ≠ "
                f"schema rows for {bad}); compile a static snapshot first"
            )
    shape0 = {t: f.shape[0] for t, f in ensembles[0].factors.items()}
    for e in ensembles[1:]:
        if {t: f.shape[0] for t, f in e.factors.items()} != shape0:
            raise ValueError(
                "stacked ensembles must share one schema (factor row "
                "domains differ)"
            )
    dtype = (jnp.bfloat16 if all(e.factor_dtype == jnp.bfloat16 for e in ensembles)
             else jnp.float32)
    factors = {
        t.name: jnp.concatenate(
            [e.factors[t.name].astype(dtype) for e in ensembles], axis=1
        )
        for t in sch.tables
    }
    leaf_values = jnp.concatenate([e.leaf_values for e in ensembles])
    offsets = [0]
    for e in ensembles:
        offsets.append(offsets[-1] + e.total_leaves)
    return StackedEnsembles(
        ensembles=list(ensembles), factors=factors,
        leaf_values=leaf_values, offsets=offsets, counter=counter,
    )
