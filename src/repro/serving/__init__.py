"""Serving: compiled ensembles, one-pass batched scoring, micro-batching.

    compile_ensemble / CompiledEnsemble  — stacked-leaf one-pass scorer
    score_grouped / score_rows / score_fresh — jitted entry points
    score_grouped_reference              — seed per-leaf loop (baseline)
    ModelRegistry / RelationalScoringService — versioned hot-swap + batcher
"""
from .compile import (
    CompiledEnsemble, KernelChannels, compile_ensemble, stack_table_factor,
)
from .multi import StackedEnsembles, stack_ensembles
from .scorer import (
    score_fresh,
    score_grouped,
    score_grouped_reference,
    score_mean_rows,
    score_rows,
)
from .service import (
    LRUCache, ModelRegistry, RelationalScoringService, ServiceOverloadedError,
    ServiceStats,
)

__all__ = [
    "CompiledEnsemble", "KernelChannels", "compile_ensemble", "stack_table_factor",
    "StackedEnsembles", "stack_ensembles",
    "score_fresh", "score_grouped", "score_grouped_reference",
    "score_mean_rows", "score_rows",
    "LRUCache", "ModelRegistry", "RelationalScoringService",
    "ServiceOverloadedError", "ServiceStats",
]
