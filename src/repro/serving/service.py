"""Async micro-batching front end for the compiled relational scorer.

Request path: ``await service.score(row_id)`` enqueues a future; the
batcher task drains the queue, coalescing up to ``max_batch`` requests
or until ``max_wait_ms`` elapses since the batch opened, runs ONE jitted
``score_rows`` gather per (model version) group, and resolves the
futures.  An LRU cache keyed by (version, row_id) short-circuits repeat
traffic before it ever reaches the queue.

Model lifecycle: a :class:`ModelRegistry` holds versioned
:class:`CompiledEnsemble`s; ``publish`` atomically installs a freshly
boosted model as latest and ``swap`` replaces the model at an existing
slot — in-flight requests keep the version they were enqueued with, new
requests pick up the change (zero-downtime hot swap).  A published model
may also be a ``MaintainedScorer`` whose state mutates in place under
table deltas: each batch then dispatches against an MVCC ``Snapshot``
pinned at batch cutoff, and the result cache is namespaced by (registry
version, slot install epoch, pinned ``data_version``, row id) — so hot
swaps, slot reuse, and concurrent delta ingest can never resurface (or
mis-file) a cached score.

Backpressure, outermost-first: queue-depth admission control (shed past
``max_queue`` while the SLO burns, or past the 4× hard cap), burn-rate
load shedding (``unhealthy`` ⇒ :class:`ServiceOverloadedError`), and a
deadline-aware batch cutoff (the coalescing window closes early when the
oldest queued request would otherwise spend more than ``deadline_frac``
of its latency budget waiting).
"""
from __future__ import annotations

import asyncio
import itertools
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..obs import MetricsRegistry, get_registry, span
from ..runtime.fault import Backoff
from .compile import CompiledEnsemble
from .scorer import score_mean_rows


class ServiceOverloadedError(RuntimeError):
    """Raised when admission control sheds a request (SLO unhealthy)."""


class LRUCache:
    """Bounded (version, row_id) → score cache with hit/miss stats,
    mirrored into ``registry``'s ``service.lru.*`` series.  The owning
    service passes its OWN per-service registry — co-hosted services
    must not mix their hit/miss series (the process-global registry is
    only the fallback for standalone caches)."""

    def __init__(self, capacity: int, registry: Optional[MetricsRegistry] = None):
        self.capacity = capacity
        self._d: "OrderedDict" = OrderedDict()
        self.hits = 0
        self.misses = 0
        reg = registry if registry is not None else get_registry()
        self._g_hits = reg.counter("service.lru.hits")
        self._g_misses = reg.counter("service.lru.misses")

    def get(self, key):
        if self.capacity <= 0 or key not in self._d:
            self.misses += 1
            self._g_misses.inc()
            return None
        self._d.move_to_end(key)
        self.hits += 1
        self._g_hits.inc()
        return self._d[key]

    def put(self, key, value):
        if self.capacity <= 0:
            return
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def __len__(self):
        return len(self._d)


class ModelRegistry:
    """Versioned store of compiled ensembles (monotonic version ids).

    ``max_versions`` bounds resident models: publishing beyond it evicts
    the oldest versions (their factors are the dominant memory cost in a
    long-running service).  Requests pinned to an evicted version fail
    with KeyError — pin only within a swap window."""

    def __init__(self, max_versions: int = 8):
        self.max_versions = max_versions
        self._models: Dict[int, CompiledEnsemble] = {}
        self._latest: Optional[int] = None
        self._ids = itertools.count(1)
        self._stacked_cache = None
        # per-slot install epoch (monotonic across the registry): bumps
        # whenever a version slot's MODEL changes — publish or in-place
        # swap — so caches keyed on (version, data_version) alone cannot
        # serve model A's scores for model B after a hot swap when both
        # happen to report the same data_version (e.g. two static
        # ensembles both defaulting to 0)
        self._gen = 0
        self._epochs: Dict[int, int] = {}

    def publish(self, ensemble: CompiledEnsemble) -> int:
        """Install a new model version and make it the serving default."""
        v = next(self._ids)
        self._models[v] = ensemble
        self._gen += 1
        self._epochs[v] = self._gen
        self._latest = v
        while len(self._models) > self.max_versions:
            old = min(self._models)
            self._models.pop(old)
            self._epochs.pop(old, None)
        return v

    def swap(self, version: int, ensemble: CompiledEnsemble) -> int:
        """Hot-swap the model AT an existing version slot (in-place
        patch / canary rollback).  The slot's epoch bumps, invalidating
        every cache keyed through :meth:`epoch` — in-flight requests
        pinned to the slot pick up the new model at their next batch."""
        if version not in self._models:
            raise KeyError(f"version {version} not resident")
        self._models[version] = ensemble
        self._gen += 1
        self._epochs[version] = self._gen
        return version

    def epoch(self, version: int) -> int:
        """Install epoch of the model currently at ``version`` — a
        registry-wide monotonic id that distinguishes successive
        occupants of one slot."""
        return self._epochs[version]

    def latest_version(self) -> int:
        if self._latest is None:
            raise LookupError("registry is empty — publish a model first")
        return self._latest

    def get(self, version: Optional[int] = None) -> Tuple[int, CompiledEnsemble]:
        v = self.latest_version() if version is None else version
        return v, self._models[v]

    def versions(self) -> List[int]:
        return sorted(self._models)

    def stacked(self, versions: Optional[List[int]] = None):
        """All (or the given) resident variants fused into one factor set
        for single-pass A/B scoring (see serving/multi.py).  Cached until
        the participating versions, their install epochs, or their
        data_versions change — the epoch term is what keeps two distinct
        models that both report data_version 0 apart across a swap."""
        from .multi import stack_ensembles

        vs = tuple(self.versions() if versions is None else versions)
        key = (vs,
               tuple(self._epochs[v] for v in vs),
               tuple(getattr(self._models[v], "data_version", 0) for v in vs))
        if self._stacked_cache is None or self._stacked_cache[0] != key:
            self._stacked_cache = (key, stack_ensembles([self._models[v] for v in vs]))
        return self._stacked_cache[1]


class ServiceStats:
    """Service accounting as named metric series (thread-safe), keeping
    the old attribute surface (``requests``/``batches``/``batched_rows``
    /``cache_hits``/``mean_batch``) as read-only views.

    Beyond the seed counters it records the TIMINGS the seed never did:
    per-request queue wait (enqueue → batch pickup), end-to-end latency
    (``score`` entry → resolved future, cache hits included), per-batch
    execute time, and the coalesced batch-size distribution — all as
    log-bucketed histograms with p50/p90/p99 summaries.  Each service
    owns its registry so co-hosted services never mix their series.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        r = self.registry
        self._requests = r.counter("service.requests")
        self._batches = r.counter("service.batches")
        self._batched_rows = r.counter("service.batched_rows")
        self._cache_hits = r.counter("service.cache_hits")
        self._rejected = r.counter("service.rejected")   # bad row ids
        self._errors = r.counter("service.errors")       # dispatch failures
        self._retries = r.counter("service.retries")     # transient redispatch
        self._shed = r.counter("service.shed")           # admission control
        self.staleness_s = r.gauge("service.staleness_s")
        self.queue_wait_ms = r.histogram("service.queue_wait_ms")
        self.latency_ms = r.histogram("service.latency_ms")
        self.batch_exec_ms = r.histogram("service.batch_exec_ms")
        self.batch_size = r.histogram("service.batch_size")

    @property
    def requests(self) -> int:
        return self._requests.value

    @property
    def batches(self) -> int:
        return self._batches.value

    @property
    def batched_rows(self) -> int:
        return self._batched_rows.value

    @property
    def cache_hits(self) -> int:
        return self._cache_hits.value

    @property
    def rejected(self) -> int:
        return self._rejected.value

    @property
    def errors(self) -> int:
        return self._errors.value

    @property
    def retries(self) -> int:
        return self._retries.value

    @property
    def shed(self) -> int:
        return self._shed.value

    @property
    def mean_batch(self) -> float:
        return self.batched_rows / max(self.batches, 1)

    def snapshot(self) -> dict:
        """p50/p99 summary dict (see
        :meth:`RelationalScoringService.stats_snapshot`)."""
        def q(h):
            s = h.summary()
            return {k: s[k] for k in ("count", "mean", "p50", "p90", "p99", "max")}
        return {
            "requests": self.requests,
            "batches": self.batches,
            "cache_hits": self.cache_hits,
            "cache_hit_rate": self.cache_hits / max(self.requests, 1),
            "rejected": self.rejected,
            "errors": self.errors,
            "retries": self.retries,
            "shed": self.shed,
            "staleness_s": self.staleness_s.value,
            "mean_batch": self.mean_batch,
            "queue_wait_ms": q(self.queue_wait_ms),
            "latency_ms": q(self.latency_ms),
            "batch_exec_ms": q(self.batch_exec_ms),
            "batch_size": q(self.batch_size),
        }


class _Request:
    __slots__ = ("row_id", "version", "future", "t_enq")

    def __init__(self, row_id: int, version: int, future: "asyncio.Future",
                 t_enq: float):
        self.row_id = row_id
        self.version = version
        self.future = future
        self.t_enq = t_enq


class RelationalScoringService:
    """Queue → coalesce → jitted batched scorer → dispatch futures.

    Live-telemetry hooks: an attached :class:`~repro.obs.slo.SLOMonitor`
    receives every request's latency/outcome plus the served model's
    data staleness, and its burn-rate state feeds BACK into the batcher
    as an overload signal — ``degraded`` collapses the coalescing window
    (drain-greedily, stop queue wait compounding the tail), ``unhealthy``
    sheds new admissions with :class:`ServiceOverloadedError` (the hook
    the ROADMAP's admission-control item extends).  An attached
    :class:`~repro.obs.flight.FlightRecorder` is fed the same
    latencies/errors so tail incidents snapshot themselves.
    """

    def __init__(
        self,
        registry: ModelRegistry,
        group_by: str,
        max_batch: int = 64,
        max_wait_ms: float = 2.0,
        cache_size: int = 4096,
        slo=None,                        # SLOMonitor, optional
        flight=None,                     # FlightRecorder, optional
        shed_when_unhealthy: bool = True,
        latency_budget_ms: Optional[float] = None,
        deadline_frac: float = 0.5,
        max_queue: Optional[int] = None,
        retry_transient: bool = True,
        extra_staleness=None,            # () -> seconds, e.g. replication lag
    ):
        self.registry = registry
        self.group_by = group_by
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.stats = ServiceStats()
        # the LRU reports into THIS service's registry, not the process
        # one — co-hosted services keep their service.lru.* series apart
        self.cache = LRUCache(cache_size, registry=self.stats.registry)
        self.slo = slo
        self.flight = flight
        self.shed_when_unhealthy = shed_when_unhealthy
        # per-request latency budget (seconds) for the deadline-aware
        # batch cutoff: explicit, else the tightest latency objective on
        # the attached SLO monitor, else none (pure max_wait coalescing).
        # Only deadline_frac of the budget may be spent waiting in the
        # coalescing window — the remainder is reserved for execution.
        if latency_budget_ms is not None:
            self.latency_budget = latency_budget_ms / 1e3
        else:
            budgets = [o.target / 1e3
                       for o in getattr(slo, "objectives", {}).values()
                       if o.kind == "latency"]
            self.latency_budget = min(budgets) if budgets else None
        self.deadline_frac = deadline_frac
        # queue-depth admission control: past max_queue while the SLO is
        # burning (state != healthy), or past the 4× hard cap regardless,
        # new requests shed with ServiceOverloadedError instead of
        # compounding everyone's queue wait.  None disables.
        self.max_queue = max_queue
        # transient-failure retry: a version dispatch that throws gets
        # ONE re-attempt after a jittered, budget-capped backoff before
        # its requests count toward service.errors — a single JAX /
        # runtime hiccup must not fail a whole coalesced batch.  The
        # budget bounds total sleep across repeated failures; once
        # exhausted, failures surface immediately until a success
        # resets it.
        self.retry_transient = retry_transient
        self._retry_backoff = Backoff(base_s=0.005, cap_s=0.05,
                                      budget_s=1.0)
        # replica wiring: an extra staleness source folded (max) into
        # the SLO staleness signal — a WAL follower passes its
        # replication lag here, so a lagging/dead writer burns the
        # staleness objective even while the local scorer itself is
        # fully caught up with everything the log delivered
        self.extra_staleness = extra_staleness
        self._q: "asyncio.Queue" = asyncio.Queue()
        self._task: Optional["asyncio.Task"] = None

    # ---------------------------------------------------------------- stats --
    def stats_snapshot(self) -> dict:
        """Point-in-time service telemetry: request/batch/cache counts
        plus p50/p90/p99 of queue wait, end-to-end latency, batch
        execute time, and the batch-size distribution."""
        return self.stats.snapshot()

    # -------------------------------------------------------------- control --
    async def start(self):
        if self._task is None:
            self._task = asyncio.get_running_loop().create_task(self._run())

    async def stop(self):
        if self._task is not None:
            await self._q.put(None)
            await self._task
            self._task = None
        # fail any request that raced in behind the stop sentinel rather
        # than leaving its caller awaiting forever
        while not self._q.empty():
            item = self._q.get_nowait()
            if item is not None and not item.future.done():
                item.future.set_exception(RuntimeError("service stopped"))

    # -------------------------------------------------------------- serving --
    def _observe_latency(self, ms: float, error: bool = False) -> None:
        self.stats.latency_ms.observe(ms)
        if self.slo is not None:
            self.slo.record_latency(ms)
            self.slo.record_request(error=error)
        if self.flight is not None:
            self.flight.observe_latency(ms)

    async def score(self, row_id: int, version: Optional[int] = None) -> float:
        """Mean prediction Σŷ/count for one row of ``group_by``."""
        if self._task is None or self._task.done():
            raise RuntimeError("service not running — call start() first")
        t0 = time.perf_counter()
        v, ens = self.registry.get(version)
        # validate per request (a bad id inside a coalesced batch must not
        # fail its co-batched neighbours); rejected requests don't count
        # toward requests/latency — they never entered the scoring path
        n = ens.n_rows(self.group_by)
        if not 0 <= row_id < n:
            self.stats._rejected.inc()
            raise IndexError(
                f"row id {row_id} out of range for table {self.group_by!r} (n_rows={n})"
            )
        # admission control: an unhealthy burn-rate state means the loop
        # is past its SLO on both windows — shed before enqueueing more
        if (self.slo is not None and self.shed_when_unhealthy
                and self.slo.state() == "unhealthy"):
            self.stats._shed.inc()
            raise ServiceOverloadedError(
                f"load shed: SLO state unhealthy "
                f"(burn rates over budget; see /healthz)")
        # queue-depth backpressure: a deep queue while the SLO burns
        # means arrivals outpace dispatch — admitting more only moves
        # the miss to a slower failure.  The 4× hard cap bounds memory
        # and worst-case queue wait even without an SLO verdict.
        if self.max_queue is not None:
            depth = self._q.qsize()
            burning = (self.slo is not None
                       and self.slo.state() != "healthy")
            if depth >= 4 * self.max_queue or (burning and depth >= self.max_queue):
                self.stats._shed.inc()
                raise ServiceOverloadedError(
                    f"load shed: queue depth {depth} over "
                    f"{'hard cap' if depth >= 4 * self.max_queue else 'limit'} "
                    f"(max_queue={self.max_queue})")
        self.stats._requests.inc()
        # cache key includes the slot's install epoch AND the model's
        # data_version: delta maintenance mutates a published
        # MaintainedScorer in place (dv bump), and a hot swap replaces
        # the model at this version outright (epoch bump) — a stale hit
        # across either would serve the wrong model's scores
        cached = self.cache.get(
            (v, self.registry.epoch(v), getattr(ens, "data_version", 0), row_id))
        if cached is not None:
            self.stats._cache_hits.inc()
            self._observe_latency((time.perf_counter() - t0) * 1e3)
            return cached
        fut = asyncio.get_running_loop().create_future()
        await self._q.put(_Request(int(row_id), v, fut, t0))
        try:
            result = await fut
        except Exception:
            self._observe_latency((time.perf_counter() - t0) * 1e3, error=True)
            raise
        self._observe_latency((time.perf_counter() - t0) * 1e3)
        return result

    async def score_many(self, row_ids, version: Optional[int] = None) -> List[float]:
        """Score a batch; sibling results survive individual failures.

        A bare gather would cancel every co-batched request the moment
        one row id is rejected.  Instead all requests run to completion
        (``return_exceptions=True``) — survivors resolve, land in the
        cache, and count in the stats — and only then is the first
        failure re-raised."""
        results = await asyncio.gather(
            *(self.score(r, version) for r in row_ids),
            return_exceptions=True,
        )
        for r in results:
            if isinstance(r, BaseException):
                raise r
        return list(results)

    # -------------------------------------------------------------- batcher --
    async def _collect(self) -> Optional[List[_Request]]:
        """One coalescing window: first request opens the batch, then
        fill until max_batch, the max_wait deadline, or — deadline-aware
        cutoff — the instant the OLDEST queued request would otherwise
        spend more than ``deadline_frac`` of its latency budget waiting.
        All clocks are ``time.perf_counter`` (the ``t_enq`` clock)."""
        first = await self._q.get()
        if first is None:
            return None
        batch = [first]
        # overload signal: once degraded, queue wait is compounding the
        # tail — stop holding batches open and drain greedily instead
        wait = self.max_wait
        if self.slo is not None and self.slo.state() != "healthy":
            wait = 0.0
        deadline = time.perf_counter() + wait
        if self.latency_budget is not None:
            deadline = min(
                deadline,
                first.t_enq + self.latency_budget * self.deadline_frac)
        while len(batch) < self.max_batch:
            try:                             # greedy drain: no await overhead
                item = self._q.get_nowait()
            except asyncio.QueueEmpty:
                # clamp at 0: under load (or with the oldest request
                # already past its cutoff) the deadline is in the past,
                # and wait_for must never see a negative timeout
                timeout = max(0.0, deadline - time.perf_counter())
                if timeout == 0.0:
                    break
                try:
                    item = await asyncio.wait_for(self._q.get(), timeout)
                except asyncio.TimeoutError:
                    break
            if item is None:
                await self._q.put(None)     # re-post the stop sentinel
                break
            batch.append(item)
        return batch

    def _frozen_view(self, ens):
        """Pin the serving view AT batch cutoff.  A maintained model
        publishes an MVCC snapshot — frozen factors/messages/join trees
        at one data_version — so a concurrent ``apply()`` can neither
        tear the gather nor slide the version between read and cache
        write.  Static ensembles are immutable already: served as-is."""
        snap = getattr(ens, "snapshot", None)
        if callable(snap):
            view = snap(roots=(self.group_by,))
            return view, view.data_version
        return ens, getattr(ens, "data_version", 0)

    def _dispatch(self, batch: List[_Request]):
        st = self.stats
        t_pick = time.perf_counter()
        for r in batch:                      # enqueue → batch pickup
            st.queue_wait_ms.observe((t_pick - r.t_enq) * 1e3)
        by_version: Dict[int, List[_Request]] = {}
        for r in batch:
            by_version.setdefault(r.version, []).append(r)
        with span("service.batch", size=len(batch),
                  versions=len(by_version)):
            for v, reqs in by_version.items():
                # per-version isolation: one version's failure resolves
                # only ITS requests exceptionally — co-batched requests
                # pinned to other versions still get their scores
                try:
                    self._dispatch_version(v, reqs)
                    self._retry_backoff.reset()
                    continue
                except Exception as e:
                    err = e
                if self.retry_transient:
                    try:
                        delay = self._retry_backoff.next_delay()
                    except RuntimeError:     # retry budget exhausted
                        delay = None
                    if delay is not None:
                        time.sleep(delay)
                        st._retries.inc()
                        try:
                            self._dispatch_version(v, reqs)
                            self._retry_backoff.reset()
                            continue
                        except Exception as e:
                            err = e
                st._errors.inc(len(reqs))
                if self.flight is not None:
                    self.flight.observe_error(err, batch_size=len(reqs))
                for r in reqs:
                    if not r.future.done():
                        r.future.set_exception(err)
        st._batches.inc()
        st._batched_rows.inc(len(batch))
        st.batch_size.observe(len(batch))

    def _dispatch_version(self, v: int, reqs: List[_Request]):
        st = self.stats
        _, ens = self.registry.get(v)
        ep = self.registry.epoch(v)
        # served-data staleness of OUR root: the wall-clock lag this
        # batch is about to resolve (the snapshot refresh below writes
        # back to the live scorer, clearing it).  Sampled from the live
        # model — the snapshot is frozen and has no lag of its own.
        stale = getattr(ens, "staleness_s", None)
        s = None
        if callable(stale):
            try:
                s = stale(self.group_by)
            except TypeError:            # provider without per-root lag
                s = stale()
        if self.extra_staleness is not None:
            # replica mode: served data lags by the WORSE of local
            # refresh lag and replication lag behind the writer's log
            s = max(s or 0.0, float(self.extra_staleness()))
        if s is not None:
            st.staleness_s.set(s)
            if self.slo is not None:
                self.slo.set_staleness(s)
        # version pin happens HERE, at batch cutoff — not re-read after
        # execution: a delta applied mid-dispatch mutates the live
        # model, but this batch gathers from the frozen view and caches
        # under the view's pinned data_version
        view, dv = self._frozen_view(ens)
        ids = np.asarray([r.row_id for r in reqs], np.int32)
        t_exec = time.perf_counter()
        mean = np.asarray(score_mean_rows(view, self.group_by, ids))
        st.batch_exec_ms.observe((time.perf_counter() - t_exec) * 1e3)
        for r, m in zip(reqs, mean):
            val = float(m)
            self.cache.put((v, ep, dv, r.row_id), val)
            if not r.future.done():
                r.future.set_result(val)

    async def _run(self):
        while True:
            batch = await self._collect()
            if batch is None:
                return
            try:
                self._dispatch(batch)
            except Exception as e:      # propagate to the callers, keep serving
                self.stats._errors.inc(len(batch))
                if self.flight is not None:
                    self.flight.observe_error(e, batch_size=len(batch))
                for r in batch:
                    if not r.future.done():
                        r.future.set_exception(e)
