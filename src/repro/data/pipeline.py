"""Deterministic, host-sharded, prefetching data pipeline.

- Host sharding: each process draws only its slice of the global batch
  (seeded by (stream seed, step, process)); restart at step N reproduces
  the exact stream — checkpoint-resume is bitwise deterministic.
- Prefetch: a background thread keeps `depth` batches ready.
- Straggler hook: the runtime watchdog can call ``reassign(host)`` to
  redistribute a slow host's shard (runtime/fault.py).
- Relational feature stage (paper integration): an optional
  (Booster, schema, group_table) triple scores examples *relationally*
  (per-fact-row Σŷ without materializing the join) and turns the scores
  into sampling weights — in-database boosted trees as a data-quality
  mixer in front of LM training.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np

from .synthetic import SyntheticLM


class TokenPipeline:
    def __init__(
        self,
        vocab: int,
        global_batch: int,
        seq_len: int,
        seed: int = 0,
        depth: int = 2,
        n_hosts: int = 1,
        host_id: int = 0,
        example_weights: Optional[np.ndarray] = None,
        make_batch: Optional[Callable] = None,
    ):
        self.spec = (global_batch, seq_len)
        self.n_hosts, self.host_id = n_hosts, host_id
        self.seed = seed
        self.gen = SyntheticLM(vocab, seed=seed)
        self.make_batch = make_batch
        self.weights = example_weights
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = 0
        self._gen = 0           # bumped on seek/reassign; stale batches dropped
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._dead_hosts: set = set()
        self._thread.start()

    # ------------------------------------------------------------ control --
    def reassign(self, host: int):
        """Straggler mitigation: fold a slow host's shard into the others."""
        self._dead_hosts.add(host)
        self._gen += 1

    def seek(self, step: int):
        """Deterministic resume: restart production at `step`."""
        self._gen += 1
        self._step = step
        with self._q.mutex:
            self._q.queue.clear()

    def stop(self):
        self._stop.set()

    # ----------------------------------------------------------- producer --
    def _host_rows(self, step: int):
        G = self.spec[0]
        alive = [h for h in range(self.n_hosts) if h not in self._dead_hosts]
        per = G // len(alive)
        mine = alive.index(self.host_id) if self.host_id in alive else 0
        return per, mine

    def _produce(self, step: int) -> Dict[str, np.ndarray]:
        G, S = self.spec
        per, mine = self._host_rows(step)
        rng = np.random.default_rng((self.seed, step, mine))
        if self.make_batch is not None:
            return self.make_batch(rng, per, S)
        if self.weights is not None:
            # importance-sample corpus docs by relational quality scores,
            # then synthesize each selected doc deterministically (same doc
            # id → same token row, across steps and hosts).  Skewed weights
            # repeat docs, so generate once per unique doc and index back.
            p = self.weights / self.weights.sum()
            keep = rng.choice(len(p), size=per, p=p)
            uniq, inv = np.unique(keep, return_inverse=True)
            rows = np.stack([
                self.gen.batch(np.random.default_rng((self.seed, int(d))), 1, S)[0]
                for d in uniq
            ])
            return {"tokens": rows[inv], "doc_ids": keep.astype(np.int64)}
        toks = self.gen.batch(rng, per, S)
        return {"tokens": toks}

    def _producer(self):
        while not self._stop.is_set():
            gen, step = self._gen, self._step
            b = self._produce(step)
            self._q.put((gen, step, b))
            if self._step == step:    # not seeked meanwhile
                self._step = step + 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self):
        while True:
            gen, _step, b = self._q.get()
            if gen == self._gen:       # drop batches produced pre-seek
                return b


def relational_example_weights(booster, trees, group_table: str) -> np.ndarray:
    """Per-row data-quality weights from a relationally-trained booster.

    Scores every fact row's Σŷ over ρ⋈J with the serving subsystem's
    compiled one-pass scorer (no join materialization, one SumProd
    evaluation) — the paper's algorithm as a production pipeline stage."""
    from repro.serving import compile_ensemble, score_grouped

    ens = compile_ensemble(booster.schema, trees)
    tot, cnt = score_grouped(ens, group_table)
    score = np.asarray(tot) / np.maximum(np.asarray(cnt), 1.0)
    w = np.exp(score - score.max())
    return w / w.sum()
