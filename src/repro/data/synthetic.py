"""Synthetic LM token streams with learnable structure.

Markov-bigram + copy/induction patterns: a model that learns anything
drives loss well below the unigram entropy floor, so the end-to-end
training example shows a real learning curve on CPU.
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, seed: int = 0, order: int = 2):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        # sparse-ish bigram transition table: each token has few successors
        self.n_succ = 8
        self.succ = rng.integers(0, vocab, (vocab, self.n_succ))
        self.probs = rng.dirichlet(np.ones(self.n_succ) * 0.5, size=vocab)

    def batch(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq), np.int32)
        tok = rng.integers(0, self.vocab, batch)
        for t in range(seq):
            out[:, t] = tok
            choice = (rng.random(batch)[:, None] >
                      np.cumsum(self.probs[tok], -1)).sum(-1)
            choice = np.minimum(choice, self.n_succ - 1)
            tok = self.succ[tok, choice]
        return out
