"""llava-next-34b [vlm] — anyres tiling happens in the (stubbed)
frontend; the backbone consumes precomputed patch embeddings
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].  Half of each
sequence is patch embeddings, half text tokens (DESIGN.md §5)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", kind="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, frontend="patches", rope_theta=1e6,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
    q_chunk=32, kv_chunk=64,
)
