"""rwkv6-1.6b [ssm] 'Finch' — attention-free, data-dependent decay
[arXiv:2404.05892; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", kind="rwkv",
    n_layers=24, d_model=2048, n_heads=32,   # heads = d_model / head_size
    d_ff=7168, vocab=65536, rwkv_head_size=64, ssm_chunk=16,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=2, d_ff=256, vocab=512,
    rwkv_head_size=64, ssm_chunk=8,
)
