"""hymba-1.5b [hybrid] — parallel attention + SSM heads, SWA with 3
global-attention layers, 128 meta tokens [arXiv:2411.13676; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", kind="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, d_head=64,
    window=1024, global_layers=(0, 15, 31),
    ssm_state=16, ssm_heads=25, meta_tokens=128, ssm_chunk=16,
    # unrolled layers → per-layer windows are static ints, which enables
    # banded (window-restricted) attention block schedules (§Perf H-1)
    scan_layers=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
    d_head=32, window=64, global_layers=(0,), ssm_state=4, ssm_heads=4,
    meta_tokens=8, ssm_chunk=8, q_chunk=32, kv_chunk=64,
)
