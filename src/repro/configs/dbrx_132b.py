"""dbrx-132b [moe] — 16 experts top-4, fine-grained
[hf:databricks/dbrx-base; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", kind="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352,
    n_experts=16, top_k=4, capacity_factor=1.25,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=128, vocab=512,
    n_experts=4, top_k=2, q_chunk=32, kv_chunk=64,
)
