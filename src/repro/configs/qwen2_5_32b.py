"""qwen2.5-32b [dense] — GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", kind="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab=152064, qkv_bias=True, rope_theta=1e6,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
    q_chunk=32, kv_chunk=64,
)
