"""Architecture registry: the 10 assigned configs + the paper's own.

Every module defines ``CONFIG`` (full, exact assignment numbers) and
``SMOKE`` (reduced same-family config for CPU tests).  ``get(name)``
returns the full config, ``get_smoke(name)`` the reduced one.

Shapes (assignment): seq_len × global_batch; decode_*/long_* lower
``serve_step`` (one token against a seq_len KV cache).  ``long_500k``
runs only for sub-quadratic archs (rwkv6, hymba) — skips recorded in
DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

from repro.models.config import ModelConfig

ARCHS = [
    "qwen2_5_32b",
    "tinyllama_1_1b",
    "llama3_405b",
    "granite_3_8b",
    "dbrx_132b",
    "llama4_scout_17b_a16e",
    "seamless_m4t_medium",
    "llava_next_34b",
    "rwkv6_1_6b",
    "hymba_1_5b",
]

# canonical external ids → module names
ALIASES = {
    "qwen2.5-32b": "qwen2_5_32b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "llama3-405b": "llama3_405b",
    "granite-3-8b": "granite_3_8b",
    "dbrx-132b": "dbrx_132b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llava-next-34b": "llava_next_34b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "hymba-1.5b": "hymba_1_5b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str               # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ALIASES.get(name, name)}")
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ALIASES.get(name, name)}")
    return mod.SMOKE


def cells(arch: str) -> List[str]:
    """Applicable shape names for an arch (assignment skip rules)."""
    cfg = get(arch)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out


def all_cells() -> List[Tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in cells(a)]
