"""granite-3-8b [dense] — GQA [hf:ibm-granite/granite-3.0-2b-base; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", kind="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab=49155, tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
    q_chunk=32, kv_chunk=64,
)
