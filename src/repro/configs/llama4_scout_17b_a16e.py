"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert,
early fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", kind="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, rope_theta=5e5,
    n_experts=16, top_k=1, shared_expert=True, capacity_factor=1.5,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=128, vocab=512,
    n_experts=4, top_k=1, q_chunk=32, kv_chunk=64,
)
