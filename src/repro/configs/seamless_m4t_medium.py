"""seamless-m4t-medium [audio] — encoder–decoder, multimodal backbone
[arXiv:2308.11596; hf].  Frontend is a stub: input_specs() supplies
precomputed speech-frame embeddings (assignment rule).  The assignment's
single seq_len splits src = tgt = seq_len/2 (DESIGN.md §5)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", kind="encdec",
    n_layers=12, enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, frontend="frames", act="gelu",
)

SMOKE = CONFIG.replace(
    n_layers=2, enc_layers=2, d_model=128, n_heads=8, n_kv_heads=8,
    d_ff=256, vocab=512, q_chunk=32, kv_chunk=64,
)
