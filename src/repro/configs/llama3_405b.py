"""llama3-405b [dense] — GQA, 128k vocab [arXiv:2407.21783; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", kind="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab=128256, rope_theta=5e5,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
    q_chunk=32, kv_chunk=64,
)
