"""The paper's own 'architecture': relational boosted regression trees.

Selectable via --arch paper-rbrt in benchmarks/examples; hyperparameters
mirror the paper's variables (m trees, L leaves via depth, τ tables, k)."""
from repro.core.trainer import BoostConfig

CONFIG = BoostConfig(n_trees=8, depth=4, mode="sketch", sketch_k=256)
SMOKE = BoostConfig(n_trees=2, depth=2, mode="sketch", sketch_k=64)
