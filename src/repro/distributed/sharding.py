"""Logical-axis sharding rules → NamedSharding for every param/state leaf.

Logical axes:
  fsdp — parameter/optimizer sharding (ZeRO-3-style). Maps to ("pod",
         "data") on the multi-pod mesh, ("data",) single-pod.
  tp   — tensor parallel (attention heads / d_ff / vocab). Maps to "model".
  dp   — batch data parallel for activations: ("pod", "data").

Rules are name-based (regex on the pytree path) with a leading-stack-dim
fixup: scanned layer stacks have an extra L axis which is never sharded.
A dimension is only sharded when divisible by the axis size — otherwise
dropped to None (GQA head counts vs tp=16 — GSPMD then chooses; see
DESIGN.md §4/§6).
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# (path regex, logical spec per trailing dim)
_RULES: List[Tuple[str, Tuple[Optional[str], ...]]] = [
    # vocab over tp, d_model over fsdp; lookup is a one-hot matmul under a
    # mesh (layers.embed) so the sharded table partitions cleanly
    (r"embed/tok$",                    ("tp", "fsdp")),
    (r"embed/head$",                   ("fsdp", "tp")),
    (r"attn/wq$|attn/wk$|attn/wv$",    ("fsdp", "tp")),
    (r"attn/wo$",                      ("tp", "fsdp")),
    (r"attn/b[qkv]$",                  ("tp",)),
    (r"xattn/wq$|xattn/wk$|xattn/wv$", ("fsdp", "tp")),
    (r"xattn/wo$",                     ("tp", "fsdp")),
    (r"xattn/b[qkv]$",                 ("tp",)),
    (r"mlp/w_gate$|mlp/w_up$",         ("fsdp", "tp")),
    (r"mlp/w_down$",                   ("tp", "fsdp")),
    (r"moe/router$",                   ("fsdp", None)),
    (r"moe/w_gate$|moe/w_up$",         ("tp", "fsdp", None)),   # experts on tp (EP)
    (r"moe/w_down$",                   ("tp", None, "fsdp")),
    (r"shared/w_gate$|shared/w_up$",   ("fsdp", "tp")),
    (r"shared/w_down$",                ("tp", "fsdp")),
    (r"mix/w[rkvg]$|mix/cr$",          ("fsdp", "tp")),
    (r"mix/wo$|mix/cv$",               ("tp", "fsdp")),
    (r"mix/ck$",                       ("fsdp", "tp")),
    (r"mix/wA$",                       ("fsdp", None)),
    (r"mix/wB$",                       (None, "tp")),
    (r"ssm/wx$|ssm/wB$|ssm/wC$",       ("fsdp", "tp")),
    (r"ssm/wdt$",                      ("fsdp", None)),
    (r"ssm/wo$",                       ("tp", "fsdp")),
    (r"ssm/conv$",                     (None, "tp")),
    (r"meta$",                         (None, None)),
]


def mesh_axes(mesh: Mesh) -> Dict[str, Tuple[str, ...]]:
    names = mesh.axis_names
    fsdp = tuple(n for n in ("pod", "data") if n in names)
    tp = ("model",) if "model" in names else ()
    return {
        "fsdp": fsdp,
        "dp": fsdp,
        "tp": tp,
        "all": fsdp + tp,
    }


def constrain(x, *logical: Optional[str]):
    """with_sharding_constraint by logical axis names — no-op outside a
    mesh context (smoke tests), drops non-divisible dims (GQA vs tp)."""
    from jax.interpreters import pxla

    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty or mesh.size == 1:
        return x
    la = mesh_axes(mesh)
    spec: List[Any] = []
    for dim, name in zip(x.shape, logical):
        axes = la.get(name) if name else None
        if axes and dim % _axis_size(mesh, axes) == 0:
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec))
    )


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def logical_to_spec(mesh: Mesh, logical: Tuple[Optional[str], ...],
                    shape: Tuple[int, ...]) -> P:
    """Resolve logical axes to a PartitionSpec, dropping non-divisible dims."""
    la = mesh_axes(mesh)
    extra = len(shape) - len(logical)
    out: List[Any] = [None] * extra
    for dim, name in zip(shape[extra:], logical):
        if name is None:
            out.append(None)
            continue
        axes = la[name]
        if axes and dim % _axis_size(mesh, axes) == 0:
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def param_shardings(mesh: Mesh, params_shape) -> Any:
    """NamedSharding pytree for a params (or ShapeDtypeStruct) pytree."""

    def assign(path, leaf):
        ps = _path_str(path)
        for pat, logical in _RULES:
            if re.search(pat, ps):
                return NamedSharding(mesh, logical_to_spec(mesh, logical, leaf.shape))
        return NamedSharding(mesh, P())  # norms, scalars: replicated

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def batch_shardings(mesh: Mesh, batch_shape) -> Any:
    """Token batches: shard the global batch dim over dp (if divisible)."""
    la = mesh_axes(mesh)
    dp = la["dp"]

    def assign(path, leaf):
        b = leaf.shape[0]
        if dp and b % _axis_size(mesh, dp) == 0:
            return NamedSharding(mesh, P(dp if len(dp) > 1 else dp[0]))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(assign, batch_shape)


_CACHE_RULES: List[Tuple[str, Tuple[Optional[str], ...]]] = [
    # trailing-dim logical specs; stacked (L, ...) leading dims padded None.
    # KV span shards over tp: sequence-sharded KV is what lets a 32k×128 or
    # 500k×1 cache fit per device (DESIGN.md §4); batch over dp.
    (r"/k$|/v$",           ("dp", "tp", None, None)),    # (B, span, Kh, dh)
    (r"/kpos$",            ("dp", "tp")),                # (B, span)
    (r"/S$",               ("dp", "tp", None, None)),    # rwkv (B, H, hs, hs)
    (r"x_last_tm$|x_last_cm$", ("dp", "tp")),            # (B, D)
    (r"ssm/h$",            ("dp", "tp", None, None)),    # (B, H, N, P)
    (r"ssm/conv$",         ("dp", None, "tp")),          # (B, 4, d_inner)
    (r"enc_out$",          ("dp", "tp", None)),          # (B, S_src, D)
    (r"enc_pos$",          ("dp", "tp")),
    (r"pos$",              ("dp",)),
]


def cache_shardings(mesh: Mesh, cache_shape) -> Any:
    """Decode/prefill caches; handles both stacked (L, …) pytrees (scan
    kinds) and per-layer lists (hybrid)."""

    def assign(path, leaf):
        ps = _path_str(path)
        for pat, logical in _CACHE_RULES:
            if re.search(pat, ps):
                return NamedSharding(
                    mesh, logical_to_spec(mesh, logical, leaf.shape)
                )
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(assign, cache_shape)


def replicated(mesh: Mesh, tree) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
