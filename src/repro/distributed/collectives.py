"""Data-parallel SumProd: the paper's inside-out pass over row-sharded
tables (DESIGN.md §4, relational pillar).

Rows of every table shard over the data axes.  Each inside-out edge does
a *local* segment-⊕ into the dense key-domain vector, then one
``psum``-style combine over the data axis (⊕ is commutative/associative
for every semiring here: + for the module semirings, min for Tropical,
max/or for Boolean) — the key-domain message is the ONLY cross-device
traffic; factor rows never move.  Grouped-by results stay row-sharded
with the grouping table.

Bandwidth: per edge per query, |key domain| × |semiring value| bytes
all-reduced — independent of row count, which is why the relational
pillar scales to thousands of nodes (the paper's n rows live sharded;
messages are the compressed boundary).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.schema import Schema
from repro.core.semiring import BooleanSR, Semiring, Tropical
from repro.core.sumprod import SumProd


def _axis_reduce(sem: Semiring, x, axis_name: str):
    if isinstance(sem, Tropical):
        return jax.lax.pmin(x, axis_name)
    if isinstance(sem, BooleanSR):
        return jax.lax.pmax(x.astype(jnp.int32), axis_name).astype(jnp.bool_)
    return jax.lax.psum(x, axis_name)


class ShardedSumProd:
    """Row-sharded inside-out over a (…, 'data', …) mesh.

    Tables are padded to a multiple of the data-axis size; key-id arrays
    travel WITH the rows (sharded args), so each shard's segment-⊕ uses
    its local ids against the shared dense key domain.
    """

    def __init__(self, schema: Schema, mesh: Mesh, axis: str = "data"):
        self.schema = schema
        self.mesh = mesh
        self.axis = axis
        self.n_shards = mesh.shape[axis]

    def _pad(self, arr, sem: Optional[Semiring] = None):
        n = arr.shape[0]
        pad = (-n) % self.n_shards
        if pad == 0:
            return arr
        if sem is None:  # index arrays: segment 0, harmless w/ zero values
            return jnp.concatenate([arr, jnp.zeros((pad,), arr.dtype)])
        return jnp.concatenate([arr, sem.zeros((pad,))])  # (pad, *value_shape)

    def __call__(self, sem: Semiring, factors: Dict[str, jnp.ndarray],
                 group_by: str):
        """Grouped query; returns per-row results for `group_by`
        (row-sharded then gathered — tests compare against SumProd)."""
        schema, mesh, axis = self.schema, self.mesh, self.axis
        jt = schema.join_tree(group_by)
        names = schema.names

        f_pad = {tn: self._pad(factors[tn], sem) for tn in factors}
        ids = {}
        for e in jt.edges:
            ids[f"c{e.child}"] = self._pad(e.child_ids)
            ids[f"p{e.parent}_{e.child}"] = self._pad(e.parent_ids)

        row_spec = P(axis) if len(sem.value_shape) == 0 else P(axis, *([None] * len(sem.value_shape)))

        def local(f_loc, ids_loc):
            f = dict(f_loc)
            for e in jt.edges:
                child, parent = names[e.child], names[e.parent]
                msg = sem.segment_add(f[child], ids_loc[f"c{e.child}"], e.n_keys)
                msg = _axis_reduce(sem, msg, axis)
                f[parent] = sem.mul(
                    f[parent], jnp.take(msg, ids_loc[f"p{e.parent}_{e.child}"], axis=0)
                )
            return f[group_by]

        in_specs = (
            {tn: row_spec for tn in f_pad},
            {k: P(axis) for k in ids},
        )
        out = shard_map(
            local, mesh=mesh, in_specs=in_specs, out_specs=row_spec,
            check_rep=False,
        )(f_pad, ids)
        return out[: schema.table(group_by).n_rows]


def reference_matches(schema: Schema, sem: Semiring, factors, group_by, mesh):
    """Test helper: sharded vs single-device results."""
    sharded = ShardedSumProd(schema, mesh)(sem, factors, group_by)
    plain = SumProd(schema)(sem, factors, group_by=group_by)
    return sharded, plain
