"""Data-parallel SPMD layer for the relational engine.

Lifts the logical-axis→NamedSharding rule machinery from
`distributed.sharding` (`mesh_axes`, `logical_to_spec`, divisibility
drop) into factor/featmat layout rules for the SumProd engine, plus a
process-wide *data-mesh context* that `serving/compile.py`,
`core/engine.py`, `incremental/maintain.py` and `incremental/retrain.py`
thread through.

Layout rules (all derived from one logical spec, "dp" on the row axis):

  factor   (n_rows, *value_shape)  → P(dp, None, ...)   rows sharded
  featmat  (d_t, n_rows)           → P(None, dp)        rows sharded
  mask     (..., n_rows)           → P(None, ..., dp)   rows sharded
  message  (n_keys, *value_shape)  → P()                replicated

A row dimension is sharded only when divisible by the data-axis size —
otherwise dropped to replicated (same rule as `logical_to_spec`; small
dimension tables replicate naturally, which is what you want: their
messages are cheap and cross-device traffic for them would dominate).

The collective point is `psum_message`: per-edge segment-⊕ messages are
computed on row shards, and the `with_sharding_constraint` to the
replicated spec makes GSPMD insert the cross-shard ⊕-combine (an
all-reduce / `psum` for the arithmetic semirings, `pmin`/`pmax` for
tropical) exactly at the message emission.  Everything downstream of a
message is replicated, so split sweeps and tree construction run
bit-identically to single-device; everything upstream (mask, mul,
segment-⊕) runs on row shards.

Bit-equality caveat: the cross-shard combine re-associates the ⊕
reduction.  For integer-valued f32 payloads (leaf-mask counts — the
whole serving path — and training stats over integer/dyadic labels)
every partial sum is exactly representable, so sharded == single-device
bit-for-bit.  Arbitrary float labels see ~1e-5 reassociation noise, the
same noise any parallel reduction has.

Complex payloads (the count-sketch semirings' frequency/coefficient
monomials) are never row-sharded: their entries are unit-modulus
complex numbers, so no partial sum is exactly representable and a
cross-shard combine would break bit-equality.  `shard_rows` /
`constrain_rows` detect the dtype and pin those arrays replicated —
sketch queries run full-shape (identically) on every device while the
count/exact-stat queries around them stay data-parallel.

No jax device state is touched at import time; meshes are built by
`launch.mesh.make_data_mesh` and installed via `use_data_mesh`.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .sharding import _axis_size, mesh_axes


def _exact_payload(x) -> bool:
    """False for payloads whose cross-shard ⊕ cannot be exact (complex
    sketch monomials) — those must stay replicated."""
    return not np.issubdtype(np.dtype(x.dtype), np.complexfloating)

# Process-wide active data mesh.  Plain module global with save/restore
# via `use_data_mesh` — mesh installation happens on the orchestrating
# thread; long-lived objects (CompiledEnsemble, MaintainedScorer,
# MaintainedEngine) capture the mesh at construction and re-enter it
# themselves, so worker threads never depend on ambient state.
_ACTIVE_MESH: Optional[Mesh] = None


def current_data_mesh() -> Optional[Mesh]:
    """The active data mesh, or None (single-device semantics)."""
    return _ACTIVE_MESH


def _resolve(mesh: Optional[Mesh]) -> Optional[Mesh]:
    """Normalize to an effective mesh: explicit arg wins, else ambient;
    size-1 meshes degrade to None (every helper becomes identity)."""
    m = mesh if mesh is not None else _ACTIVE_MESH
    if m is None or m.size <= 1:
        return None
    return m


def data_axis_size(mesh: Optional[Mesh] = None) -> int:
    """Number of shards along the data axes (1 when no mesh is active)."""
    m = _resolve(mesh)
    if m is None:
        return 1
    return _axis_size(m, mesh_axes(m)["dp"])


@contextmanager
def use_data_mesh(mesh: Optional[Mesh]):
    """Install `mesh` as the ambient data mesh for the dynamic extent.

    `use_data_mesh(None)` explicitly clears the context (single-device
    semantics), so an unsharded ensemble traced inside a sharded
    orchestrator stays deterministic.
    """
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    try:
        yield mesh
    finally:
        _ACTIVE_MESH = prev


def _row_spec(ndim: int, row_axis: int, mesh: Mesh, rows: int) -> P:
    """PartitionSpec sharding `row_axis` over dp iff divisible."""
    dp = mesh_axes(mesh)["dp"]
    if not dp or rows % _axis_size(mesh, dp) != 0:
        return P()
    spec = [None] * ndim
    spec[row_axis] = dp if len(dp) > 1 else dp[0]
    return P(*spec)


# -- placement (device_put): host arrays → sharded/replicated buffers --

def shard_rows(x, mesh: Optional[Mesh] = None, row_axis: int = 0):
    """device_put with rows sharded over the data axes (factor layout:
    row_axis=0; featmat layout: row_axis=1; mask layout: row_axis=-1).
    Identity when no mesh is active or rows aren't divisible."""
    m = _resolve(mesh)
    if m is None:
        return x
    ra = row_axis % x.ndim
    spec = (_row_spec(x.ndim, ra, m, x.shape[ra])
            if _exact_payload(x) else P())
    return jax.device_put(x, NamedSharding(m, spec))


def shard_factor(x, mesh: Optional[Mesh] = None):
    """(n_rows, *value_shape) factor: rows sharded, values local."""
    return shard_rows(x, mesh, row_axis=0)


def shard_featmat(x, mesh: Optional[Mesh] = None):
    """(d_t, n_rows) feature matrix: rows (axis 1) sharded."""
    return shard_rows(x, mesh, row_axis=1)


def shard_factors(factors: Dict[str, jax.Array],
                  mesh: Optional[Mesh] = None) -> Dict[str, jax.Array]:
    """Shard a {table: factor} dict by rows (per-table divisibility)."""
    m = _resolve(mesh)
    if m is None:
        return factors
    return {t: shard_factor(f, m) for t, f in factors.items()}


def replicate_put(x, mesh: Optional[Mesh] = None):
    """device_put replicated across the mesh (leaf values, small tables)."""
    m = _resolve(mesh)
    if m is None:
        return x
    return jax.device_put(x, NamedSharding(m, P()))


# -- in-graph constraints (with_sharding_constraint): trace-time hints --

def constrain_rows(x, mesh: Optional[Mesh] = None, row_axis: int = 0):
    """In-graph row-sharding constraint.  Use where sharded placement
    can't stick — closure constants under jit (DirectEngine bases) or
    intermediate factors inside a vmapped query."""
    m = _resolve(mesh)
    if m is None:
        return x
    ra = row_axis % x.ndim
    spec = (_row_spec(x.ndim, ra, m, x.shape[ra])
            if _exact_payload(x) else P())
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, spec))


def psum_message(x, mesh: Optional[Mesh] = None):
    """THE collective point: constrain a per-edge message (or grouped
    query output) to replicated.  With row-sharded inputs upstream,
    GSPMD lowers this to the cross-shard segment-⊕ combine — the psum.
    Identity when no mesh is active (bit-identical single-device path).
    """
    m = _resolve(mesh)
    if m is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, P()))


# `replicate` reads better at engine boundaries where the intent is
# "make this deterministic for host-side control flow", not a reduction.
replicate = psum_message


def mesh_fingerprint(mesh: Optional[Mesh] = None) -> Optional[Dict[str, int]]:
    """{axis: size} for BENCH fingerprints; None when unsharded."""
    m = _resolve(mesh)
    if m is None:
        return None
    return {k: int(v) for k, v in m.shape.items()}


def is_row_sharded(x, mesh: Optional[Mesh] = None, row_axis: int = 0) -> bool:
    """True if `x` actually carries a row-sharded placement (test hook)."""
    m = _resolve(mesh)
    if m is None:
        return False
    sh = getattr(x, "sharding", None)
    if sh is None:
        return False
    spec = getattr(sh, "spec", None)
    if spec is None:
        return False
    ra = row_axis % x.ndim
    return len(spec) > ra and spec[ra] is not None
