import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every jax import: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY in this process; smoke tests
# and benchmarks see the single real CPU device.

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from functools import partial  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                      # noqa: E402
from repro.distributed.sharding import (       # noqa: E402
    batch_shardings, cache_shardings, logical_to_spec, mesh_axes,
    param_shardings, replicated,
)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import input_specs, make_train_step, n_micro  # noqa: E402
from repro.models import Model                 # noqa: E402
from repro.optim import adamw                  # noqa: E402

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell against ShapeDtypeStruct stand-ins.  Proves the distribution
config is coherent — sharding mismatches, compile-time OOM, and
unsupported collectives all fail HERE, without hardware.  Artifacts
(memory analysis, cost analysis, collective census) feed EXPERIMENTS.md
§Dry-run and benchmarks/roofline.py.
"""

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def collective_census(hlo: str):
    """Count collective ops + total result bytes from compiled HLO text."""
    census = {c: {"count": 0, "bytes": 0} for c in COLLECTIVES}
    pat = re.compile(
        r"=\s*(\w+)\[([\d,]*)\]\S*\s+(all-gather|all-reduce|reduce-scatter"
        r"|all-to-all|collective-permute)\(",
    )
    dsize = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1,
             "f64": 8, "s8": 1, "u8": 1, "c64": 8, "s64": 8, "u64": 8}
    for m in pat.finditer(hlo):
        dt, dims, op = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        census[op]["count"] += 1
        census[op]["bytes"] += n * dsize.get(dt, 4)
    return census


def opt_shardings(mesh, pshard, opt_shape):
    rep = NamedSharding(mesh, P())
    return adamw.OptState(
        step=rep,
        m=pshard,
        v=pshard,
        master=jax.tree.map(lambda _: rep, opt_shape.master),
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool, save_hlo=None):
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = configs.get(arch)
    model = Model(cfg)
    mode, specs = input_specs(arch, shape_name)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = param_shardings(mesh, params_shape)
    rep = NamedSharding(mesh, P())

    with mesh:
        if mode == "train":
            ocfg = adamw.AdamWConfig()
            opt_shape = jax.eval_shape(partial(adamw.init, ocfg), params_shape)
            oshard = opt_shardings(mesh, pshard, opt_shape)
            bshard = batch_shardings(mesh, specs["batch"])
            dp = 1
            for a in mesh_axes(mesh)["dp"]:
                dp *= mesh.shape[a]
            G = configs.SHAPES[shape_name].global_batch
            step = make_train_step(model, ocfg, n_micro(arch, G, dp))
            lowered = jax.jit(
                step,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard,
                               jax.tree.map(lambda _: rep,
                                            {"loss": 0, "grad_norm": 0, "lr": 0})),
                donate_argnums=(0, 1),
            ).lower(params_shape, opt_shape, specs["batch"])
        elif mode == "prefill":
            bshard = batch_shardings(mesh, specs["batch"])
            cache_shape = jax.eval_shape(
                lambda p, b: model.prefill(p, b)[1], params_shape, specs["batch"]
            )
            cshard = cache_shardings(mesh, cache_shape)
            B = configs.SHAPES[shape_name].global_batch
            logit_shard = NamedSharding(
                mesh, logical_to_spec(mesh, ("dp", "tp"), (B, cfg.padded_vocab))
            )
            lowered = jax.jit(
                model.prefill,
                in_shardings=(pshard, bshard),
                out_shardings=(logit_shard, cshard),
            ).lower(params_shape, specs["batch"])
        else:  # decode
            cshard = cache_shardings(mesh, specs["cache"])
            B = specs["tokens"].shape[0]
            tok_shard = NamedSharding(mesh, logical_to_spec(mesh, ("dp",), (B,)))
            logit_shard = NamedSharding(
                mesh, logical_to_spec(mesh, ("dp", "tp"), (B, cfg.padded_vocab))
            )
            lowered = jax.jit(
                model.decode_step,
                in_shardings=(pshard, cshard, tok_shard),
                out_shardings=(logit_shard, cshard),
                donate_argnums=(1,),
            ).lower(params_shape, specs["cache"], specs["tokens"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    census = collective_census(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mode": mode,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "per_device_bytes": {
            "arguments": mem.argument_size_in_bytes,
            "outputs": mem.output_size_in_bytes,
            "temps": mem.temp_size_in_bytes,
            "aliased": mem.alias_size_in_bytes,
            "total_live": mem.argument_size_in_bytes + mem.temp_size_in_bytes
                          + mem.output_size_in_bytes - mem.alias_size_in_bytes,
        },
        "cost_analysis": {
            "flops_per_device_loopbody_once": cost.get("flops", -1.0),
            "bytes_accessed": cost.get("bytes accessed", -1.0),
            "transcendentals": cost.get("transcendentals", -1.0),
        },
        "collectives_hlo": census,
    }
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        cells = configs.all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (cached)")
                continue
            try:
                rec = run_cell(
                    arch, shape, mp,
                    save_hlo=os.path.join(args.out, tag + ".hlo")
                    if args.save_hlo else None,
                )
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                gb = rec["per_device_bytes"]["total_live"] / 2**30
                print(f"[ok]   {tag}: {gb:.2f} GiB/device, "
                      f"compile {rec['compile_s']}s")
            except Exception as e:  # noqa: BLE001 — record and continue
                n_fail += 1
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
                with open(path + ".err", "w") as f:
                    f.write(traceback.format_exc())
    print("dry-run complete;", ("%d FAILURES" % n_fail) if n_fail else "all passed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
