"""Pre-JAX-import device-count forcing for the launch CLIs.

`--devices N` multiplies one host CPU into N XLA devices via
`--xla_force_host_platform_device_count` — the standard way to prove
mesh-sharded programs without hardware.  The flag only works if it is
in `XLA_FLAGS` **before** the first `import jax` anywhere in the
process, so each CLI module calls :func:`apply_early_device_flags` as
its very first import, ahead of every `repro.*` import that pulls jax
in.  (`python -m repro.launch.X` executes no package-level code first:
`repro`/`repro.launch` are namespace packages.)

This module itself must therefore import nothing but the stdlib.
"""
from __future__ import annotations

import os
import sys
import warnings


def apply_early_device_flags(argv=None) -> int:
    """Scan argv for ``--devices N`` / ``--devices=N`` and, when found,
    append the forced-host-device flag to ``XLA_FLAGS``.  Returns the
    requested count (0 = flag absent, leave the platform alone).

    Must run before jax is imported; if it already is, the request
    cannot take effect and a warning says so instead of silently running
    single-device.
    """
    argv = list(sys.argv[1:] if argv is None else argv)
    n = 0
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            n = int(argv[i + 1])
            break
        if a.startswith("--devices="):
            n = int(a.split("=", 1)[1])
            break
    if n <= 0:
        return 0
    if "jax" in sys.modules:
        warnings.warn(
            "--devices ignored: jax was already imported before the "
            "device flag could be applied (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} in the "
            "environment instead)")
        return 0
    flag = f"--xla_force_host_platform_device_count={n}"
    prev = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in prev:
        os.environ["XLA_FLAGS"] = (prev + " " + flag).strip()
    return n


def add_device_args(ap) -> None:
    """Register the shared --devices/--mesh arguments on a CLI parser.

    --devices is consumed by :func:`apply_early_device_flags` before
    argparse runs; it is declared here so it shows in --help and
    round-trips cleanly.  --mesh N runs the workload data-parallel over
    the first N visible devices (0 = single-device, the default;
    -1 = all visible devices).
    """
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="force N host XLA devices (CPU proof recipe; "
                         "applied before jax import)")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="shard factors/featmats data-parallel over N "
                         "devices (0 = off, -1 = all visible)")


def resolve_mesh(args):
    """Build the data mesh an argparse namespace asks for (or None).

    Imports jax lazily — safe to call only after
    :func:`apply_early_device_flags` has run.
    """
    n = getattr(args, "mesh", 0)
    if not n:
        return None
    from .mesh import make_data_mesh

    return make_data_mesh(None if n < 0 else n)
