"""Batched serving driver: prefill a prompt batch, decode N tokens.

Same Model code as the dry-run serve cells; on CPU this drives the
reduced configs (examples/serving.py), on a pod the full ones.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import Model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = (configs.get if args.full else configs.get_smoke)(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.frontend == "patches":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, args.prompt_len // 2, cfg.d_model)) * 0.02,
            jnp.float32)
        batch["tokens"] = batch["tokens"][:, : args.prompt_len - args.prompt_len // 2]
    if cfg.is_encdec:
        batch["src_frames"] = jnp.asarray(
            rng.standard_normal((args.batch, args.prompt_len // 2, cfg.d_model)) * 0.02,
            jnp.float32)
        batch["tokens"] = batch["tokens"][:, : args.prompt_len // 2]

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [toks]
    t0 = time.time()
    for _ in range(args.decode_tokens):
        logits, cache = decode(params, cache, toks)
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0

    seqs = np.stack([np.asarray(t) for t in out], 1)
    tput = args.batch * args.decode_tokens / t_decode
    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.batch}x{args.prompt_len}")
    print(f"decode:  {t_decode*1e3:.1f} ms for {args.decode_tokens} steps "
          f"({tput:.1f} tok/s, incl. first-call compile)")
    print("sampled continuations (greedy):")
    for row in seqs[: min(4, args.batch)]:
        print("  ", row[:16].tolist())
    return seqs


if __name__ == "__main__":
    main()
