"""Delta-stream driver: train → compile → maintain under live table churn.

Trains a booster on a synthetic relational workload, compiles the
ensemble, wraps it in a :class:`MaintainedScorer`, publishes it to the
serving registry, and then streams random insert/delete/update batches
at the tables.  After every batch the maintained grouped scores are
refreshed along the changed tables' root paths only; periodically they
are audited against a full recompute oracle (fresh ``compile_ensemble``
on the effective live tables).  Reports per-batch maintenance latency,
the segment-⊕ edge ratio vs full recompute, and the audit verdict.

    PYTHONPATH=src python -m repro.launch.stream_deltas --batches 20

Sharded maintenance: `--devices 8 --mesh 8` forces 8 host XLA devices
(before any jax import — hence the leading _devices import) and keeps
the capacity-padded factors row-sharded over a ("data",) mesh.
"""
from __future__ import annotations

from repro.launch._devices import (          # noqa: I001  (must precede
    add_device_args, apply_early_device_flags, resolve_mesh)   # jax imports)

apply_early_device_flags()

import argparse
import os
import time

import numpy as np

from repro.core import BoostConfig, Booster, QueryCounter
from repro.distributed import spmd
from repro.incremental import MaintainedScorer
from repro.obs import (
    FlightRecorder, PeriodicSampler, SLOMonitor, TelemetryServer,
    format_summary_table, get_registry, parse_slo_spec,
)
from repro.relational import generators
from repro.serving import ModelRegistry, compile_ensemble


def build_schema(args):
    if args.schema == "star":
        return generators.star_schema(seed=args.seed, n_fact=args.n_fact,
                                      n_dim=args.n_dim)
    if args.schema == "chain":
        return generators.chain_schema(seed=args.seed, n_rows=args.n_fact)
    if args.schema == "snowflake":
        return generators.snowflake_schema(seed=args.seed, n_fact=args.n_fact,
                                           n_dim=args.n_dim)
    raise ValueError(args.schema)


def audit(ms: MaintainedScorer, group: str) -> float:
    """Max |maintained − fresh-recompute| over live rows (want 0.0)."""
    tot_o, cnt_o = ms.recompute_oracle(group)
    tot_m, cnt_m = ms.grouped_cached(group)
    err_t = float(np.abs(np.asarray(tot_m) - np.asarray(tot_o)).max())
    err_c = float(np.abs(np.asarray(cnt_m) - np.asarray(cnt_o)).max())
    return max(err_t, err_c)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--schema", default="star",
                    choices=["star", "chain", "snowflake"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-fact", type=int, default=1000)
    ap.add_argument("--n-dim", type=int, default=48)
    ap.add_argument("--trees", type=int, default=4)
    ap.add_argument("--depth", type=int, default=3)
    ap.add_argument("--batches", type=int, default=12)
    ap.add_argument("--ops", type=int, default=8)
    ap.add_argument("--audit-every", type=int, default=4)
    ap.add_argument("--wal-dir", metavar="DIR", default=None,
                    help="durable delta log: append every applied batch to "
                         "DIR/wal.log (crash-consistent; a follower process "
                         "can tail it with serve_relational --follow DIR). "
                         "An existing log is recovered and resumed.")
    ap.add_argument("--checkpoint-every", type=int, default=None, metavar="N",
                    help="checkpoint the dynamic store to DIR/ckpt every N "
                         "batches (recovery = newest checkpoint + WAL tail)")
    ap.add_argument("--wal-sync-every", type=int, default=8,
                    help="fsync the log every N appends (group commit)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve /metricsz /healthz /statusz /tracez on this "
                         "port (0 = ephemeral) for the duration of the stream")
    ap.add_argument("--slo", metavar="SPEC", default=None,
                    help="e.g. 'latency=100ms@0.99,staleness=2s' — per-batch "
                         "maintenance latency + served-data staleness")
    ap.add_argument("--flight", type=int, default=None, metavar="N",
                    help="flight-recorder ring of the last N spans with "
                         "latency-triggered FLIGHT_deltas_*.json dumps")
    ap.add_argument("--flight-latency-ms", type=float, default=None)
    ap.add_argument("--sample", metavar="PATH", default=None,
                    help="append periodic metric-snapshot deltas to this JSONL")
    ap.add_argument("--sample-interval", type=float, default=1.0)
    add_device_args(ap)
    args = ap.parse_args(argv)

    mesh = resolve_mesh(args)
    schema = build_schema(args)
    group = schema.label_table
    cfg = BoostConfig(n_trees=args.trees, depth=args.depth, mode="sketch",
                      ssr_mode="off")
    with spmd.use_data_mesh(mesh):
        trees, _ = Booster(schema, cfg).fit()
        counter = QueryCounter()
        ms = MaintainedScorer(compile_ensemble(schema, trees), counter=counter)
    if mesh is not None:
        print(f"data-parallel over {spmd.data_axis_size(mesh)} devices")
    wal = ckpt_dir = None
    if args.wal_dir:
        from repro.incremental.recover import recover_scorer, save_checkpoint
        from repro.incremental.wal import WalWriter, wal_path

        ckpt_dir = os.path.join(args.wal_dir, "ckpt")
        if os.path.exists(wal_path(args.wal_dir)) or os.path.isdir(ckpt_dir):
            with spmd.use_data_mesh(mesh):
                ms, rep = recover_scorer(
                    compile_ensemble(schema, trees), args.wal_dir,
                    ckpt_dir if os.path.isdir(ckpt_dir) else None,
                    counter=counter)
            print(f"recovered: checkpoint lsn {rep.checkpoint_lsn} + "
                  f"{rep.replayed} replayed → data_v{rep.recovered_lsn} "
                  f"({rep.tail_bytes_discarded}B torn tail discarded)")
        wal = WalWriter(args.wal_dir, sync_every=args.wal_sync_every,
                        repair=True).attach(ms.state)
    registry = ModelRegistry()
    v = registry.publish(ms)
    ms.grouped_cached(group)                      # prime the message cache
    full_edges = len(schema.join_tree(group).edges)
    print(f"published v{v}: {ms.total_leaves} stacked leaves, "
          f"{schema.n_tables} tables; full pass = {full_edges} segment-⊕ edges")

    slo = (SLOMonitor(parse_slo_spec(args.slo),
                      fast_window_s=5.0, slow_window_s=30.0)
           if args.slo else None)
    flight = None
    if args.flight:
        flight = FlightRecorder(
            capacity=args.flight, name="deltas",
            latency_trigger_ms=args.flight_latency_ms, cooldown_s=5.0,
        ).start()
    telemetry = None
    if args.metrics_port is not None:
        telemetry = TelemetryServer(
            slo=slo, flight=flight, port=args.metrics_port,
            status_fn=lambda: {"data_version": ms.data_version,
                               "staleness_s": ms.staleness_s()},
        )
        telemetry.start_in_thread()
        print(f"telemetry: {telemetry.url('/metricsz')}  "
              f"{telemetry.url('/healthz')}")
    sampler = None
    if args.sample:
        sampler = PeriodicSampler(
            args.sample, interval_s=args.sample_interval,
            extra_fn=lambda: {"data_version": ms.data_version,
                              "staleness_s": ms.staleness_s(),
                              "slo_state": slo.state() if slo else None},
        ).start()

    stream = generators.delta_stream(
        schema, ms.live_rows, seed=args.seed + 1,
        n_batches=args.batches, ops_per_batch=args.ops,
    )
    lat, inc_edges = [], 0
    for bi, batch in enumerate(stream):
        e0 = counter.edges
        t0 = time.perf_counter()
        dv = ms.apply(batch)
        if slo is not None:
            slo.set_staleness(ms.staleness_s())   # applied, not yet served
        ms.grouped_cached(group)                  # path-restricted refresh
        lat.append((time.perf_counter() - t0) * 1e3)
        if slo is not None:
            slo.record_latency(lat[-1])
            slo.record_request(error=False)
            slo.set_staleness(ms.staleness_s())   # refreshed → 0 again
        if flight is not None:
            flight.observe_latency(lat[-1], batch=bi)
        inc_edges += counter.edges - e0
        ops = sum(d.n_ops for d in batch)
        note = ""
        if (bi + 1) % args.audit_every == 0:
            err = audit(ms, group)
            note = f"  audit max|Δ|={err:.1e}" + ("  OK" if err == 0.0 else "  DRIFT!")
        if (ckpt_dir is not None and args.checkpoint_every
                and (bi + 1) % args.checkpoint_every == 0):
            path = save_checkpoint(ms.state, ckpt_dir)
            note += f"  ckpt→{os.path.basename(path)}"
        print(f"batch {bi:>3} ({ops} ops, {len(batch)} tables) → data_v{dv} "
              f"edges={counter.edges - e0} {lat[-1]:6.1f} ms{note}")
    n = len(lat)
    print(f"\n{n} batches: mean maintenance {np.mean(lat):.1f} ms; "
          f"segment-⊕ edges {inc_edges} incremental vs {full_edges * n} "
          f"full-recompute ({full_edges * n / max(inc_edges, 1):.1f}× fewer)")
    err = audit(ms, group)
    print(f"final audit vs fresh recompute: max|Δ|={err:.1e} "
          + ("(exact)" if err == 0.0 else "(DRIFT)"))
    if wal is not None:
        wal.heartbeat()                  # followers see a live, idle writer
        durable = wal.sync()
        wal.close()
        print(f"WAL: durable through lsn {durable} "
              f"({os.path.getsize(wal.path)} bytes at {wal.path})")
    if slo is not None:
        rep = slo.evaluate()
        print(f"SLO state: {rep['state']}  "
              + "  ".join(f"{n}: burn {o['burn_fast']:.2f} [{o['state']}]"
                          for n, o in rep["objectives"].items()))
    if sampler is not None:
        sampler.stop()
        print(f"wrote {sampler.samples} telemetry samples to {args.sample}")
    if telemetry is not None:
        telemetry.stop_thread()
    if flight is not None:
        flight.stop()
        st = flight.status()
        print(f"flight recorder: {st['buffered']} spans buffered, "
              f"{len(st['dumps'])} dump(s)")
    print(format_summary_table(get_registry().snapshot(),
                               title="stream_deltas metrics"))
    return err


if __name__ == "__main__":
    main()
