"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax
import; smoke tests see the single real device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a (data, model) mesh — smoke/examples."""
    n = len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_data_mesh(n: int | None = None):
    """1-D ("data",) mesh over the first `n` devices (all by default).

    This is the mesh the relational engine shards over: factors/featmats
    split by rows along "data", per-edge SumProd messages ⊕-combined
    across it (see `distributed.spmd`).  Install with
    `spmd.use_data_mesh(make_data_mesh())`.  CPU-only proof recipe:
    set `XLA_FLAGS=--xla_force_host_platform_device_count=8` before the
    first jax import (the launch CLIs' `--devices` flag does this).
    """
    import numpy as np

    devs = jax.devices()
    if n is not None:
        if n > len(devs):
            raise ValueError(
                f"requested {n} mesh devices but only {len(devs)} visible "
                f"(use --devices / XLA_FLAGS to force host devices first)")
        devs = devs[:n]
    return jax.sharding.Mesh(np.asarray(devs), ("data",))
