"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax
import; smoke tests see the single real device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a (data, model) mesh — smoke/examples."""
    n = len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
