"""Retrain-stream driver: train → stream drift → delta-driven refits.

Trains an :class:`IncrementalBooster` (boosting queries answered from
maintained messages), then streams concept-drift batches (feature
rewrites + label shifts) at the tables.  After every batch the booster
measures residual drift with a cheap sketched-SSR query (served from
the message cache) and, above the threshold, warm-starts new trees on
the frozen ensemble's residuals.  Periodically the model is audited
against a full-refit oracle — a from-scratch ``Booster.fit`` on the
effective live tables — reporting MSE parity and the segment-⊕ edge
emissions both routes spent (the queries-avoided ratio).

    PYTHONPATH=src python -m repro.launch.retrain_stream --batches 8

Sharded retraining: `--devices 8 --mesh 8` forces 8 host XLA devices
(before any jax import — hence the leading _devices import) and runs
the maintained engine's query bases row-sharded over a ("data",) mesh.
"""
from __future__ import annotations

from repro.launch._devices import (          # noqa: I001  (must precede
    add_device_args, apply_early_device_flags, resolve_mesh)   # jax imports)

apply_early_device_flags()

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core import BoostConfig, Booster, materialize_join, predict_rows
from repro.distributed import spmd
from repro.incremental import IncrementalBooster
from repro.obs import (
    FlightRecorder, PeriodicSampler, SLOMonitor, TelemetryServer,
    enable_tracing, format_summary_table, get_registry, get_tracer,
    parse_slo_spec,
)
from repro.relational import generators


def build_schema(args):
    if args.schema == "star":
        return generators.star_schema(seed=args.seed, n_fact=args.n_fact,
                                      n_dim=args.n_dim)
    if args.schema == "chain":
        return generators.chain_schema(seed=args.seed, n_rows=args.n_fact)
    if args.schema == "snowflake":
        return generators.snowflake_schema(seed=args.seed, n_fact=args.n_fact,
                                           n_dim=args.n_dim)
    raise ValueError(args.schema)


def audit(ib: IncrementalBooster, cfg: BoostConfig):
    """(mse_incremental, mse_full_refit, full_refit_edges) on the live
    join, with the full refit sized to the incremental ensemble."""
    eff = ib.effective_schema()
    full = Booster(eff, BoostConfig(
        n_trees=len(ib.trees), depth=cfg.depth, mode=cfg.mode,
        sketch_k=cfg.sketch_k, ssr_mode="off", seed=cfg.seed,
        split_mode=cfg.split_mode, hist_bins=cfg.hist_bins,
    ))
    trees_f, _ = full.fit()
    J = materialize_join(eff)
    X = jnp.stack([J[c] for (_, c) in eff.features], axis=1)
    y = np.asarray(J[eff.label_column])
    mse_i = float(np.mean((y - np.asarray(predict_rows(ib.trees, X))) ** 2))
    mse_f = float(np.mean((y - np.asarray(predict_rows(trees_f, X))) ** 2))
    return mse_i, mse_f, full.counter.edges


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--schema", default="star",
                    choices=["star", "chain", "snowflake"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-fact", type=int, default=400)
    ap.add_argument("--n-dim", type=int, default=24)
    ap.add_argument("--trees", type=int, default=3)
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--rows-per-batch", type=int, default=8)
    ap.add_argument("--new-trees", type=int, default=1)
    ap.add_argument("--drift-threshold", type=float, default=0.05)
    ap.add_argument("--max-trees", type=int, default=None)
    ap.add_argument("--audit-every", type=int, default=4)
    ap.add_argument("--split-mode", default="exact",
                    choices=["exact", "hist"],
                    help="hist = quantile-histogram sweep with "
                         "incrementally maintained bins (core/hist.py)")
    ap.add_argument("--hist-bins", type=int, default=256)
    ap.add_argument("--trace", metavar="PATH", nargs="?",
                    const="trace_retrain.json", default=None,
                    help="record spans (sweep, message emission, plan "
                         "refresh) and write a Chrome trace loadable in "
                         "Perfetto, plus PATH.jsonl")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve /metricsz /healthz /statusz /tracez on this "
                         "port (0 = ephemeral) while the stream runs")
    ap.add_argument("--slo", metavar="SPEC", default=None,
                    help="e.g. 'latency=500ms@0.95,staleness=10s' — per-batch "
                         "refit latency + delta-staleness burn rates")
    ap.add_argument("--flight", type=int, default=None, metavar="N",
                    help="flight-recorder ring of the last N spans with "
                         "latency-triggered FLIGHT_retrain_*.json dumps")
    ap.add_argument("--flight-latency-ms", type=float, default=None)
    ap.add_argument("--sample", metavar="PATH", default=None,
                    help="append periodic metric-snapshot deltas to this JSONL")
    ap.add_argument("--sample-interval", type=float, default=1.0)
    add_device_args(ap)
    args = ap.parse_args(argv)

    if args.trace:
        enable_tracing()

    mesh = resolve_mesh(args)
    schema = build_schema(args)
    cfg = BoostConfig(n_trees=args.trees, depth=args.depth, mode="sketch",
                      ssr_mode="off", seed=args.seed,
                      split_mode=args.split_mode, hist_bins=args.hist_bins)
    with spmd.use_data_mesh(mesh):
        ib = IncrementalBooster(schema, cfg)
    if mesh is not None:
        print(f"data-parallel over {spmd.data_axis_size(mesh)} devices")
    t0 = time.perf_counter()
    ib.fit()
    print(f"initial fit: {len(ib.trees)} trees in "
          f"{time.perf_counter() - t0:.1f}s — {ib.counter.count} queries, "
          f"{ib.counter.edges} segment-⊕ edges "
          f"(cache hit rate {ib.engine.cache.hit_rate:.2f})")

    slo = (SLOMonitor(parse_slo_spec(args.slo),
                      fast_window_s=5.0, slow_window_s=30.0)
           if args.slo else None)
    flight = None
    if args.flight:
        flight = FlightRecorder(
            capacity=args.flight, name="retrain",
            latency_trigger_ms=args.flight_latency_ms, cooldown_s=5.0,
        ).start()
    telemetry = None
    if args.metrics_port is not None:
        telemetry = TelemetryServer(
            slo=slo, flight=flight, port=args.metrics_port,
            status_fn=lambda: {"n_trees": len(ib.trees),
                               "staleness_s": ib.staleness_s()},
        )
        telemetry.start_in_thread()
        print(f"telemetry: {telemetry.url('/metricsz')}  "
              f"{telemetry.url('/healthz')}")
    sampler = None
    if args.sample:
        sampler = PeriodicSampler(
            args.sample, interval_s=args.sample_interval,
            extra_fn=lambda: {"n_trees": len(ib.trees),
                              "staleness_s": ib.staleness_s(),
                              "slo_state": slo.state() if slo else None},
        ).start()

    stream = generators.drift_stream(
        schema, ib.live_rows, seed=args.seed + 1,
        n_batches=args.batches, rows_per_batch=args.rows_per_batch,
    )
    inc_edges_total = 0
    for bi, batch in enumerate(stream):
        t0 = time.perf_counter()
        rep = ib.refit(deltas=batch, n_new_trees=args.new_trees,
                       drift_threshold=args.drift_threshold,
                       max_trees=args.max_trees)
        dt = (time.perf_counter() - t0) * 1e3
        inc_edges_total += rep.edges
        if slo is not None:
            slo.record_latency(dt)
            slo.record_request(error=False)
            slo.set_staleness(ib.staleness_s())
        if flight is not None:
            flight.observe_latency(dt, batch=bi, refitted=rep.refitted)
        action = (f"+{rep.n_new} trees → {rep.n_trees}" if rep.refitted
                  else "kept model")
        note = ""
        if (bi + 1) % args.audit_every == 0:
            mse_i, mse_f, full_edges = audit(ib, cfg)
            note = (f"  audit: mse {mse_i:.3f} vs full-refit {mse_f:.3f} "
                    f"({full_edges} edges for the oracle)")
        print(f"batch {bi:>3}: drift={rep.drift:7.3f} {action:>18} "
              f"edges={rep.edges:>4} {dt:7.1f} ms{note}")

    mse_i, mse_f, full_edges = audit(ib, cfg)
    print(f"\n{args.batches} drift batches: {inc_edges_total} incremental "
          f"segment-⊕ edges total; one full refit of the final model costs "
          f"{full_edges} ({full_edges * args.batches} for refit-every-batch, "
          f"{full_edges * args.batches / max(inc_edges_total, 1):.1f}× more)")
    print(f"final model: mse {mse_i:.3f} vs full-refit oracle {mse_f:.3f}; "
          f"message-cache hit rate {ib.engine.cache.hit_rate:.2f}")
    if slo is not None:
        rep = slo.evaluate()
        print(f"SLO state: {rep['state']}  "
              + "  ".join(f"{n}: burn {o['burn_fast']:.2f} [{o['state']}]"
                          for n, o in rep["objectives"].items()))
    if sampler is not None:
        sampler.stop()
        print(f"wrote {sampler.samples} telemetry samples to {args.sample}")
    if telemetry is not None:
        telemetry.stop_thread()
    if flight is not None:
        flight.stop()
        st = flight.status()
        print(f"flight recorder: {st['buffered']} spans buffered, "
              f"{len(st['dumps'])} dump(s)")
    # one-screen exit summary instead of scrolling back through batches
    print(format_summary_table(get_registry().snapshot(),
                               title="retrain_stream metrics"))
    if args.trace:
        n = get_tracer().dump_chrome_trace(args.trace)
        get_tracer().dump_jsonl(args.trace + ".jsonl")
        print(f"wrote {n} spans to {args.trace} (chrome://tracing / Perfetto)")
    return mse_i, mse_f


if __name__ == "__main__":
    main()
