"""Step builders + input specs for every (arch × shape) cell.

``input_specs(arch, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input (no device allocation) — the dry-run
lowers against these.  ``make_train_step`` builds the production step:
microbatched gradient accumulation (lax.scan), fp32 accumulation, global
grad-norm clip, sharded AdamW, optional count-sketch gradient
compression on the cross-pod axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import Model
from repro.models.config import ModelConfig
from repro.optim import adamw

# per-arch microbatch count for train_4k (global batch 256); chosen so
# per-device activations stay within v5e HBM at the production mesh.
# Clamp with n_micro(arch, dp): the microbatch must stay shardable over dp.
N_MICRO = {
    "qwen2_5_32b": 16,
    "tinyllama_1_1b": 8,
    "llama3_405b": 16,
    "granite_3_8b": 16,
    "dbrx_132b": 16,
    "llama4_scout_17b_a16e": 16,
    "seamless_m4t_medium": 8,
    "llava_next_34b": 16,
    "rwkv6_1_6b": 8,
    "hymba_1_5b": 8,
}


def n_micro(arch: str, global_batch: int, dp_size: int) -> int:
    """Accumulation steps such that microbatch size ≥ dp (stays sharded)."""
    return max(1, min(N_MICRO.get(arch, 8), global_batch // max(dp_size, 1)))


def _tokens_spec(B, S):
    return jax.ShapeDtypeStruct((B, S), jnp.int32)


def batch_specs(cfg: ModelConfig, shape: configs.ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStructs for one global batch of this arch × shape."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.is_encdec:
        return {
            "src_frames": jax.ShapeDtypeStruct((B, S // 2, cfg.d_model), dt),
            "tokens": _tokens_spec(B, S // 2),
        }
    if cfg.frontend == "patches":
        return {
            "patches": jax.ShapeDtypeStruct((B, S // 2, cfg.d_model), dt),
            "tokens": _tokens_spec(B, S - S // 2),
        }
    return {"tokens": _tokens_spec(B, S)}


def input_specs(arch: str, shape_name: str):
    """(mode, specs dict) for the dry-run: train batch, prefill batch, or
    (cache, tokens) for decode."""
    cfg = configs.get(arch)
    shape = configs.SHAPES[shape_name]
    model = Model(cfg)
    if shape.mode == "train":
        return "train", {"batch": batch_specs(cfg, shape)}
    if shape.mode == "prefill":
        return "prefill", {"batch": batch_specs(cfg, shape)}
    # decode: KV cache of seq_len, one new token
    B, S = shape.global_batch, shape.seq_len
    src = S // 2 if cfg.is_encdec else 0
    cache = jax.eval_shape(lambda: model.init_cache(B, S, src_len=src))
    return "decode", {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


def split_micro(batch, n_micro: int):
    """(G, ...) → (n_micro, G/n_micro, ...) for scan-based accumulation."""
    return jax.tree.map(
        lambda a: a.reshape((n_micro, a.shape[0] // n_micro) + a.shape[1:]), batch
    )


def make_train_step(model: Model, ocfg: adamw.AdamWConfig, n_micro: int,
                    compressor=None):
    """Returns train_step(params, opt_state, batch) → (params, opt_state, metrics).

    compressor: optional GradCompressor (count-sketch, optim/grad_compress)
    applied to the accumulated gradient before the optimizer — the paper's
    sketch machinery as a distributed-optimization trick.
    """

    def train_step(params, opt_state, batch):
        micro = split_micro(batch, n_micro)

        def body(acc, mb):
            g_acc, l_acc = acc
            (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
                params, mb
            )
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads
            )
            return (g_acc, l_acc + metrics["ce"]), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(body, (g0, jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        if compressor is not None:
            grads = compressor(grads)
        params, opt_state, stats = adamw.apply(ocfg, params, grads, opt_state)
        metrics = {"loss": loss_sum / n_micro, **stats}
        return params, opt_state, metrics

    return train_step


def make_eval_loss(model: Model):
    def eval_loss(params, batch):
        loss, metrics = model.loss(params, batch)
        return metrics["ce"]

    return eval_loss
