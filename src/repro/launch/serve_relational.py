"""Relational serving driver: train → compile → micro-batch serve.

Trains a booster on a synthetic relational workload, compiles the
ensemble into the one-pass scorer, publishes it to a versioned registry,
and drives the async micro-batching service with synthetic interactive
traffic (zipf-skewed row ids — the regime where the LRU cache earns its
keep).  Ends with a hot-swap: a refreshed model is published mid-traffic
and new requests pick it up with zero downtime.

    PYTHONPATH=src python -m repro.launch.serve_relational --requests 2000

Sharded serving: `--devices 8 --mesh 8` forces 8 host XLA devices (set
before any jax import — that's why the _devices import leads) and
compiles the ensemble with row-sharded factors over a ("data",) mesh.
"""
from __future__ import annotations

from repro.launch._devices import (          # noqa: I001  (must precede
    add_device_args, apply_early_device_flags, resolve_mesh)   # jax imports)

apply_early_device_flags()

import argparse
import asyncio
import dataclasses
import os
import time

import numpy as np

from repro.core import BoostConfig, Booster, QueryCounter
from repro.distributed import spmd
from repro.obs import (
    FlightRecorder, PeriodicSampler, SLOMonitor, TelemetryServer,
    enable_tracing, format_summary_table, get_registry, get_tracer,
    merge_snapshots, parse_slo_spec,
)
from repro.relational import generators
from repro.serving import (
    ModelRegistry, RelationalScoringService, ServiceOverloadedError,
    compile_ensemble,
)


def build_schema(args):
    if args.schema == "star":
        return generators.star_schema(seed=args.seed, n_fact=args.n_fact, n_dim=args.n_dim)
    if args.schema == "chain":
        return generators.chain_schema(seed=args.seed, n_rows=args.n_fact)
    if args.schema == "snowflake":
        return generators.snowflake_schema(seed=args.seed, n_fact=args.n_fact, n_dim=args.n_dim)
    raise ValueError(args.schema)


def train(schema, args, seed=0):
    cfg = BoostConfig(n_trees=args.trees, depth=args.depth, mode="sketch",
                      ssr_mode="off", seed=seed)
    booster = Booster(schema, cfg)
    trees, _ = booster.fit()
    return trees


async def drive(service, n_rows, n_requests, concurrency, zipf_a, registry,
                schema, args, counter, telemetry=None, hot_swap=True):
    rng = np.random.default_rng(1)
    ids = np.minimum(rng.zipf(zipf_a, n_requests) - 1, n_rows - 1)
    await service.start()
    if telemetry is not None:
        await telemetry.start()
        print(f"telemetry: {telemetry.url('/metricsz')}  "
              f"{telemetry.url('/healthz')}  {telemetry.url('/statusz')}  "
              f"{telemetry.url('/tracez')}")
    # jit warmup outside the SLO clock: the first batch pays compile
    # time, which would read as an instant budget burn and trip the
    # shedder before any real traffic
    saved_slo, service.slo = service.slo, None
    await service.score_many(ids[:64].tolist())
    service.slo = saved_slo
    shed_chunks = 0
    t0 = time.perf_counter()
    for chunk in np.array_split(ids, max(1, n_requests // concurrency)):
        try:
            await service.score_many(chunk.tolist())
        except ServiceOverloadedError:   # open loop: shed work is dropped
            shed_chunks += 1
    dt = time.perf_counter() - t0
    qps = n_requests / dt
    if shed_chunks:
        print(f"admission control shed {shed_chunks} chunk(s) "
              f"({service.stats.shed} requests) while unhealthy")
    snap = service.stats_snapshot()
    lat, qw = snap["latency_ms"], snap["queue_wait_ms"]
    print(f"served {snap['requests']} requests in {dt:.2f}s → {qps:,.0f} QPS")
    print(f"latency: p50 {lat['p50']:.2f} ms, p99 {lat['p99']:.2f} ms "
          f"(queue wait p50 {qw['p50']:.2f} / p99 {qw['p99']:.2f} ms)")
    print(f"batches: {snap['batches']} (mean size {snap['mean_batch']:.1f}), "
          f"cache hit rate {100 * snap['cache_hit_rate']:.1f}%")

    if hot_swap:
        # hot swap: publish a refreshed model mid-traffic (same kernel
        # route, query accounting and mesh placement as v1)
        with spmd.use_data_mesh(getattr(args, "_mesh", None)):
            v2 = registry.publish(compile_ensemble(
                schema, train(schema, args, seed=7),
                use_kernel=args.kernel, counter=counter,
            ))
        more = rng.integers(0, n_rows, 64)
        try:
            out = await service.score_many(more.tolist())
            print(f"hot-swapped to version {v2}; {len(out)} post-swap "
                  f"requests OK (sample score {out[0]:+.3f})")
        except ServiceOverloadedError:
            print(f"hot-swapped to version {v2}; post-swap requests shed "
                  f"(SLO state unhealthy)")
    if service.slo is not None:
        rep = service.slo.evaluate()
        objs = "  ".join(
            f"{n}: burn {o['burn_fast']:.2f}/{o['burn_slow']:.2f} [{o['state']}]"
            for n, o in rep["objectives"].items())
        print(f"SLO state: {rep['state']}  ({objs})")
    if telemetry is not None:
        await telemetry.stop()
    await service.stop()
    return qps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--schema", default="star",
                    choices=["star", "chain", "snowflake"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-fact", type=int, default=2000)
    ap.add_argument("--n-dim", type=int, default=64)
    ap.add_argument("--trees", type=int, default=5)
    ap.add_argument("--depth", type=int, default=3)
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--concurrency", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=1.0)
    ap.add_argument("--cache-size", type=int, default=4096)
    ap.add_argument("--zipf", type=float, default=1.3)
    ap.add_argument("--kernel", action="store_true",
                    help="route the segment-⊕ through the Pallas kernel")
    ap.add_argument("--follow", metavar="WAL_DIR", default=None,
                    help="follower mode: recover a read-only replica from "
                         "this WAL dir (+ its ckpt/ checkpoints) and tail "
                         "the writer's log live; replication lag feeds the "
                         "SLO staleness objective (degrade-only — a dead "
                         "writer degrades the replica, never kills it)")
    ap.add_argument("--follow-poll-ms", type=float, default=10.0,
                    help="follower tail-poll interval")
    ap.add_argument("--heartbeat-grace-s", type=float, default=5.0,
                    help="writer idle time beyond which the follower "
                         "reports the idle age as staleness (writer "
                         "presumed dead past its heartbeat cadence)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record spans and write a Chrome trace "
                         "(open in Perfetto) plus PATH.jsonl")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve /metricsz /healthz /statusz /tracez on this "
                         "port (0 = ephemeral, printed on start)")
    ap.add_argument("--slo", metavar="SPEC", default=None,
                    help="SLO objectives, e.g. "
                         "'latency=50ms@0.99,errors=0.01,staleness=5s' — "
                         "burn-rate state feeds /healthz and admission control")
    ap.add_argument("--flight", type=int, default=None, metavar="N",
                    help="always-on flight recorder keeping the last N spans "
                         "(O(1) memory ring; dumps FLIGHT_serve_*.json)")
    ap.add_argument("--flight-latency-ms", type=float, default=None,
                    help="dump the flight ring when a request exceeds this "
                         "latency (requires --flight)")
    ap.add_argument("--sample", metavar="PATH", default=None,
                    help="append periodic metric-snapshot deltas to this "
                         "JSONL time series")
    ap.add_argument("--sample-interval", type=float, default=1.0)
    add_device_args(ap)
    args = ap.parse_args(argv)

    if args.trace:
        enable_tracing()

    mesh = resolve_mesh(args)
    args._mesh = mesh                       # drive()'s hot-swap recompile
    schema = build_schema(args)
    with spmd.use_data_mesh(mesh):
        trees = train(schema, args)
        counter = QueryCounter()
        ens = compile_ensemble(schema, trees, use_kernel=args.kernel,
                               counter=counter)
    group = schema.label_table
    print(f"compiled ensemble: {ens.n_trees} trees, {ens.total_leaves} stacked "
          f"leaves over {schema.n_tables} tables (group_by={group})"
          + (f" [data-parallel over {spmd.data_axis_size(mesh)} devices]"
             if mesh is not None else ""))

    # follower mode: the served model is a recovered replica driven by a
    # WAL tail from another process's writer, not the fresh compile
    follower = None
    serve_model = ens
    if args.follow:
        from repro.incremental.recover import recover_scorer
        from repro.incremental.wal import WalFollower

        ckpt_dir = os.path.join(args.follow, "ckpt")
        with spmd.use_data_mesh(mesh):
            serve_model, rep = recover_scorer(
                ens, args.follow,
                ckpt_dir if os.path.isdir(ckpt_dir) else None,
                counter=counter)
        print(f"follower: recovered to data_v{rep.recovered_lsn} "
              f"(checkpoint lsn {rep.checkpoint_lsn} + {rep.replayed} "
              f"replayed, {rep.tail_bytes_discarded}B torn tail discarded)")
        follower = WalFollower(
            args.follow, serve_model.apply, start_lsn=rep.recovered_lsn,
            poll_interval_s=args.follow_poll_ms / 1e3).start()

    slo = None
    if args.slo:
        objectives = parse_slo_spec(args.slo)
        if follower is not None:
            # a dead/lagging writer must degrade the replica (serve
            # stale), never shed its traffic — cap staleness at degraded
            objectives = [dataclasses.replace(o, degrade_only=True)
                          if o.kind == "staleness" else o
                          for o in objectives]
        slo = SLOMonitor(objectives,
                         fast_window_s=5.0, slow_window_s=30.0)
    flight = None
    if args.flight:
        flight = FlightRecorder(
            capacity=args.flight, name="serve",
            latency_trigger_ms=args.flight_latency_ms, cooldown_s=5.0,
        ).start()

    registry = ModelRegistry()
    v1 = registry.publish(serve_model)
    extra_staleness = None
    if follower is not None:
        grace = args.heartbeat_grace_s

        def extra_staleness():
            # served data lags by the undrained log tail; once drained,
            # a writer silent past its heartbeat cadence is presumed
            # dead and its idle age becomes the staleness signal
            return max(follower.replication_lag_s(),
                       max(0.0, follower.writer_idle_s() - grace))

    service = RelationalScoringService(
        registry, group, max_batch=args.max_batch,
        max_wait_ms=args.max_wait_ms, cache_size=args.cache_size,
        slo=slo, flight=flight, extra_staleness=extra_staleness,
    )
    telemetry = None
    if args.metrics_port is not None:
        telemetry = TelemetryServer(
            registries=[get_registry(), service.stats.registry],
            slo=slo, flight=flight, port=args.metrics_port,
            status_fn=lambda: {
                "model_version": registry.latest_version(),
                "stats": service.stats_snapshot(),
            },
        )
    sampler = None
    if args.sample:
        sampler = PeriodicSampler(
            args.sample, interval_s=args.sample_interval,
            registries=[get_registry(), service.stats.registry],
            extra_fn=lambda: {"slo_state": slo.state() if slo else None},
        ).start()
    n_rows = schema.table(group).n_rows
    qps = asyncio.run(drive(service, n_rows, args.requests, args.concurrency,
                            args.zipf, registry, schema, args, counter,
                            telemetry=telemetry, hot_swap=follower is None))
    if follower is not None:
        try:
            follower.stop(drain=True)
            print(f"follower: applied through lsn {follower.applied_lsn}, "
                  f"replication lag {follower.replication_lag_s():.3f}s, "
                  f"writer idle {follower.writer_idle_s():.1f}s")
        except Exception as e:           # noqa: BLE001 — report, don't die
            print(f"follower stopped with error: {e}")
    if sampler is not None:
        sampler.stop()
        print(f"wrote {sampler.samples} telemetry samples to {args.sample}")
    if flight is not None:
        flight.stop()
        st = flight.status()
        print(f"flight recorder: {st['buffered']} spans buffered, "
              f"{len(st['dumps'])} dump(s), {st['suppressed']} suppressed")
    print(f"SumProd evaluations for all traffic: {counter.count} "
          f"(seed loop would need {args.trees * 2 ** args.depth + 1} per bulk pass)")
    # one-screen exit summary: process-wide series ⊎ this service's
    print(format_summary_table(
        merge_snapshots(get_registry().snapshot(),
                        service.stats.registry.snapshot()),
        title="serve_relational metrics"))
    if args.trace:
        n = get_tracer().dump_chrome_trace(args.trace)
        get_tracer().dump_jsonl(args.trace + ".jsonl")
        print(f"wrote {n} spans to {args.trace} (chrome://tracing / Perfetto)")
    return qps


if __name__ == "__main__":
    main()
