"""Production training driver.

Wires every substrate together: mesh → shardings → data pipeline →
microbatched train step → watchdog → async checkpoints → retry/restore.
Runs the reduced (smoke) configs end-to-end on CPU (examples/) and the
full configs on a real pod (same code path; only --full and the mesh
change).

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama_1_1b \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import Checkpointer
from repro.data.pipeline import TokenPipeline
from repro.distributed.sharding import batch_shardings, param_shardings, replicated
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.optim import adamw
from repro.optim.grad_compress import CountSketchCompressor
from repro.runtime.fault import StepWatchdog, run_with_retries


def make_batch_for(cfg, rng, B, S, gen):
    b = {"tokens": gen.batch(rng, B, S)}
    if cfg.frontend == "patches":
        b["patches"] = rng.standard_normal((B, S // 2, cfg.d_model)).astype(np.float32) * 0.02
        b["tokens"] = b["tokens"][:, : S - S // 2]
    if cfg.is_encdec:
        b["src_frames"] = rng.standard_normal((B, S // 2, cfg.d_model)).astype(np.float32) * 0.02
        b["tokens"] = b["tokens"][:, : S // 2]
    return b


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--full", action="store_true", help="full config (pod scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", type=int, default=0,
                    help="count-sketch ratio (0 = off)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (configs.get if args.full else configs.get_smoke)(args.arch)
    model = Model(cfg)
    mesh = make_host_mesh()
    ocfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    compressor = (
        CountSketchCompressor(ratio=args.compress_grads)
        if args.compress_grads else None
    )
    step_fn = make_train_step(model, ocfg, args.n_micro, compressor=compressor)

    params = model.init(jax.random.PRNGKey(0))
    opt_state = adamw.init(ocfg, params)
    pshard = param_shardings(mesh, params)
    params = jax.device_put(params, pshard)

    ckpt = Checkpointer(args.ckpt_dir)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        start = ckpt.latest_step()
        params, opt_state = ckpt.restore(start, (params, opt_state))
        params = jax.device_put(params, pshard)
        print(f"resumed from step {start}")

    from repro.data.synthetic import SyntheticLM

    gen = SyntheticLM(cfg.vocab, seed=1)
    pipe = TokenPipeline(
        cfg.vocab, args.batch, args.seq, seed=1,
        make_batch=partial(make_batch_for, cfg, gen=gen),
    )
    wd = StepWatchdog(on_straggler=lambda s, dt, ema: print(
        f"[watchdog] straggler step {s}: {dt:.2f}s vs ema {ema:.2f}s"))

    with mesh:
        jstep = jax.jit(step_fn, donate_argnums=(0, 1))
        t_start = time.time()
        for step in range(start, args.steps):
            batch = next(pipe)
            batch = jax.device_put(batch, batch_shardings(mesh, batch))

            def do(state, b):
                p, o = state
                return jstep(p, o, b)

            with wd.time_step(step):
                params, opt_state, metrics = run_with_retries(
                    do, (params, opt_state), batch,
                    on_failure=lambda a, e: print(f"[retry {a}] {e}"),
                )
            if step % args.log_every == 0 or step == args.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                print(json.dumps({"step": step, **{k: round(v, 4) for k, v in m.items()}}))
            if args.ckpt_every and step and step % args.ckpt_every == 0:
                ckpt.save(step, (params, opt_state))
        ckpt.save(args.steps, (params, opt_state), blocking=True)
        print(f"done in {time.time()-t_start:.1f}s; straggler steps: "
              f"{wd.straggler_steps}")
    pipe.stop()
    return params


if __name__ == "__main__":
    main()
