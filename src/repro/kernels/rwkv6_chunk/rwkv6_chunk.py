"""Pallas TPU kernel: chunked RWKV-6 WKV recurrence.

Grid (B·H, n_chunks): the chunk axis is sequential on TPU, so the
(hs × hs) f32 state lives in VMEM scratch and flows across chunk steps
— HBM traffic is exactly r/k/v/w in + out out (the memory-optimal
schedule for a linear recurrence).  Within a chunk all math is dense
(c × c and c × hs matmuls on the MXU) with the stable all-non-positive
exponent formulation from models/rwkv6.

VMEM per program (c = 16, hs = 64, f32):
  4 tiles (c, hs) + E (c, c, hs) + A (c, c) + state (hs, hs)
  ≈ (4·1k + 16k + 0.25k + 4k) · 4 B ≈ 100 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state, *, c, hs):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    r = r_ref[0]                                  # (c, hs) f32
    k = k_ref[0]
    v = v_ref[0]
    w = w_ref[0]                                  # log-decay ≤ 0
    u = u_ref[0]                                  # (1, hs) bonus

    cum = jnp.cumsum(w, axis=0)                   # (c, hs) ≤ 0
    cum_excl = cum - w
    # intra-chunk pairwise decays: all exponents ≤ 0 → stable
    E = jnp.exp(
        jnp.clip(cum_excl[:, None, :] - cum[None, :, :], -60.0, 0.0)
    )                                             # (c, c, hs)
    A = jnp.einsum("id,jd,ijd->ij", r, k, E)
    mask = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    A = jnp.where(mask, A, 0.0)
    diag = jnp.sum(r * u * k, axis=-1)            # (c,)
    out = jnp.dot(A, v, preferred_element_type=jnp.float32) + diag[:, None] * v
    rW = r * jnp.exp(cum_excl)
    out = out + jnp.dot(rW, state[...], preferred_element_type=jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)

    kW = k * jnp.exp(cum[-1:, :] - cum)
    state[...] = jnp.exp(cum[-1, :])[:, None] * state[...] + jnp.dot(
        kW.T, v, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_chunk(r, k, v, logw, u, chunk: int = 16, interpret: bool = True):
    """r/k/v/logw: (B, S, H, hs) f32; u: (H, hs).  S % chunk == 0.
    Returns (B, S, H, hs)."""
    B, S, H, hs = r.shape
    nc = S // chunk
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, hs)
    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(logw)
    uf = jnp.tile(u, (B, 1)).reshape(B * H, 1, hs)
    out = pl.pallas_call(
        functools.partial(_kernel, c=chunk, hs=hs),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hs), jnp.float32),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hs), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, hs), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, hs), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, hs), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1, hs), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hs), lambda b, i: (b, i, 0)),
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    return out.reshape(B, H, S, hs).transpose(0, 2, 1, 3)
