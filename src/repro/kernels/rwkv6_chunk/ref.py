"""Oracle: the chunked RWKV-6 WKV from models/rwkv6 (itself validated
against the step-by-step recurrence in tests/test_archs.py)."""
from repro.models.rwkv6 import rwkv_chunked as rwkv6_chunk_ref  # noqa: F401
