"""jit'd wrapper matching models/rwkv6.time_mix's call signature."""
from __future__ import annotations

from .rwkv6_chunk import rwkv6_chunk as _kernel
from .ref import rwkv6_chunk_ref  # noqa: F401


def rwkv6_chunk(r, k, v, logw, u, chunk: int = 16, interpret: bool = True):
    return _kernel(r, k, v, logw, u, chunk=chunk, interpret=interpret)
