"""Pallas TPU kernel: signed scatter-add into k buckets (count sketch).

Used by the sketch-semiring leaves and by gradient compression
(optim/grad_compress).  TPU adaptation: random scatter is slow on TPU
(serializes through scalar memory), so the kernel reformulates each
input tile's contribution as a **one-hot × value matmul** on the MXU:

    sketch_tile[k] = Σ_t onehot(buckets[t])[k] · signs[t] · x[t]
                   = (onehot_matrix ᵀ · (signs ⊙ x))

The grid walks input tiles; bucket-tile partial sketches accumulate in
the output block (revisited across grid steps — Pallas guarantees
sequential grid order on TPU, so the read-modify-write is safe).
VMEM: x/bucket/sign tiles (nt each) + one-hot (nt × k) f32 ≤ ~2 MB at
nt=512, k=1024.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, b_ref, s_ref, o_ref, *, k: int):
    t = pl.program_id(0)
    x = x_ref[...]                                   # (nt,)
    b = b_ref[...]
    s = s_ref[...]
    oh = (b[:, None] == jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], k), 1))
    contrib = jnp.dot(
        (x * s)[None, :], oh.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )[0]

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += contrib.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "tile", "interpret"))
def count_sketch(x: jnp.ndarray, buckets: jnp.ndarray, signs: jnp.ndarray,
                 k: int, tile: int = 512, interpret: bool = True) -> jnp.ndarray:
    """x/buckets/signs: (n,) → (k,).  n padded to the tile; padded lanes
    carry sign 0 so they contribute nothing."""
    n = x.shape[0]
    pad = (-n) % tile
    if pad:
        x = jnp.pad(x, (0, pad))
        buckets = jnp.pad(buckets, (0, pad))
        signs = jnp.pad(signs, (0, pad))
    grid = (x.shape[0] // tile,)
    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((k,), lambda i: (0,)),
        interpret=interpret,
    )(x.astype(jnp.float32), buckets, signs.astype(jnp.float32))
