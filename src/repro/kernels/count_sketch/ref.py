"""Oracle: signed scatter-add count sketch (pure jnp)."""
import jax
import jax.numpy as jnp


def count_sketch_ref(x: jnp.ndarray, buckets: jnp.ndarray, signs: jnp.ndarray,
                     k: int) -> jnp.ndarray:
    """x, buckets, signs: (n,) → (k,) sketch  S·x."""
    return jax.ops.segment_sum(x * signs, buckets, num_segments=k)
