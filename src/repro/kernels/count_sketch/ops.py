"""jit'd wrapper: count sketch from a Hash2 family (matches core.sketch)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.sketch import Hash2
from .count_sketch import count_sketch
from .ref import count_sketch_ref  # noqa: F401


def count_sketch_op(x: jnp.ndarray, h: Hash2, interpret: bool = True) -> jnp.ndarray:
    idx = jnp.arange(x.shape[0])
    return count_sketch(x, h.bucket(idx), h.sign(idx), h.k, interpret=interpret)
