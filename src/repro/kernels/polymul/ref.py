"""Oracle for the sketch-semiring ⊗: batched circular convolution mod z^k
(pure jnp, FFT form — exactly PolyCoeff.mul)."""
import jax.numpy as jnp


def poly_mul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a, b: (..., k) real coefficient vectors → (..., k) circular product."""
    k = a.shape[-1]
    fa = jnp.fft.rfft(a, n=k, axis=-1)
    fb = jnp.fft.rfft(b, n=k, axis=-1)
    return jnp.fft.irfft(fa * fb, n=k, axis=-1).astype(a.dtype)
