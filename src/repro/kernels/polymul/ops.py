"""jit'd public wrapper: drop-in ⊗ for PolyCoeff factors of any batch rank."""
from __future__ import annotations

import jax.numpy as jnp

from .polymul import poly_mul
from .ref import poly_mul_ref  # noqa: F401  (re-exported oracle)


def poly_mul_op(a: jnp.ndarray, b: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Circular conv mod z^k over trailing axis; leading dims flattened
    into the kernel batch."""
    shape = jnp.broadcast_shapes(a.shape, b.shape)
    a = jnp.broadcast_to(a, shape).reshape(-1, shape[-1])
    b = jnp.broadcast_to(b, shape).reshape(-1, shape[-1])
    return poly_mul(a, b, interpret=interpret).reshape(shape)
