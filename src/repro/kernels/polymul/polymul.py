"""Pallas TPU kernel: batched circular polynomial multiplication mod z^k.

The ⊗ of the paper's sketch semiring (§3).  TPU adaptation: instead of
the paper's FFT (O(k log k), latency-bound on the VPU for the k ≤ 1024
regime the sketch uses), each product row is a **circulant matmul** on
the MXU: c = a ⊛ b = C(a)·b where C(a)[i, j] = a[(i − j) mod k].  The
systolic array runs k×k×batch MACs at peak; for k ≤ 1024 this beats an
FFT pipeline and needs no complex support.

Grid: one program per batch tile.  VMEM per program:
  a-tile (bt, k) + b-tile (bt, k) + circulant (k, k) + out (bt, k)
  = (2·bt·k + k² + bt·k) · 4 B ≤ ~0.5 MB at bt=64, k=256 — well inside
  the ~16 MB VMEM budget; k is padded to the 128-lane boundary upstream.

Building C(a) in-kernel: broadcasted-iota row/col indices, gather-free
formulation via jnp.take along the flattened (i−j) mod k index — in
interpret mode this runs the same Python; on TPU Mosaic lowers it to
vector shuffles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref, *, k: int):
    a = a_ref[...]                                     # (bt, k)
    b = b_ref[...]                                     # (bt, k)
    ii = jax.lax.broadcasted_iota(jnp.int32, (k, k), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (k, k), 1)
    idx = jnp.mod(ii - jj, k)                          # (k, k) circulant index

    def one(row_a, row_b):
        C = jnp.take(row_a, idx, axis=0)               # (k, k) circulant of a
        return jnp.dot(C, row_b, preferred_element_type=jnp.float32)

    o_ref[...] = jax.vmap(one)(a, b).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def poly_mul(a: jnp.ndarray, b: jnp.ndarray, batch_tile: int = 8,
             interpret: bool = True) -> jnp.ndarray:
    """a, b: (B, k) → (B, k) circular products.  k should be a power of
    two (the sketch guarantees this); B is padded to the tile."""
    B, k = a.shape
    bt = min(batch_tile, B)
    pad = (-B) % bt
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    grid = (a.shape[0] // bt,)
    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, k), lambda i: (i, 0)),
        interpret=interpret,
    )(a, b)
    return out[:B]
