"""Pallas TPU kernel: fused blockwise (flash) attention forward.

The perf-critical layer of every assigned transformer.  Grid is
(batch·heads, q_blocks, kv_blocks); TPU executes the grid sequentially
minor-to-major, so the kv axis revisits the same output block while the
running max `m`, denominator `l`, and accumulator live in VMEM scratch —
the textbook online-softmax recurrence, never materializing (S × S)
scores in HBM.

VMEM per program (qc = kc = 128, dh = 128, f32):
  q (qc,dh) + k,v (kc,dh) + acc (qc,dh) + m,l (qc) + s/p (qc,kc)
  ≈ 4 · 128·128 · 4 B + … ≈ 0.35 MB  → far under budget; the q/kv tile
  pair can be raised to 512/1024 on v5e for better MXU utilization
  (block shapes are parameters).

Causality skips nothing in the grid (masked instead) — a known ~2×
upper-bound on wasted work for causal shapes; the masked-block-skip
refinement is a TODO recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m, l, *, causal, qc, kc, nk, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, -jnp.inf)
        l[...] = jnp.zeros_like(l)

    q = q_ref[0].astype(jnp.float32) * scale           # (qc, dh)
    k = k_ref[0].astype(jnp.float32)                   # (kc, dh)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (qc, kc)
    if causal:
        qpos = qi * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
        kpos = ki * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
        s = jnp.where(qpos >= kpos, s, -1e30)

    m_new = jnp.maximum(m[...], s.max(-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m[...] - m_new)
    l[...] = l[...] * corr + p.sum(-1)
    acc[...] = acc[...] * corr[:, None] + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32
    )
    m[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc[...] / jnp.maximum(l[...][:, None], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "q_block", "kv_block", "interpret")
)
def flash_attention(q, k, v, causal: bool = True, q_block: int = 128,
                    kv_block: int = 128, interpret: bool = True):
    """q/k/v: (BH, S, dh) → (BH, S, dh).  S padded to block multiples
    (padding keys are masked out by the causal/position test when causal;
    for non-causal the caller must pass S % kv_block == 0)."""
    BH, S, dh = q.shape
    qc = min(q_block, S)
    kc = min(kv_block, S)
    pad_q = (-S) % qc
    pad_k = (-S) % kc
    assert causal or (pad_q == 0 and pad_k == 0)
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[1] // qc
    nk = k.shape[1] // kc
    out = pl.pallas_call(
        functools.partial(
            _kernel, causal=causal, qc=qc, kc=kc, nk=nk,
            scale=1.0 / np.sqrt(dh),
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qc, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kc, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kc, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qc, dh), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((qc, dh), jnp.float32),
            pltpu.VMEM((qc,), jnp.float32),
            pltpu.VMEM((qc,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
