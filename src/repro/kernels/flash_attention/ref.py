"""Oracle: dense softmax attention per (batch·head), causal optional."""
import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, causal: bool = True):
    """q/k/v: (BH, S, dh) → (BH, S, dh)."""
    BH, S, dh = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q, k) / np.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s.astype(jnp.float32), -1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)
