"""jit'd wrapper with GQA head grouping (matches models/layers shapes)."""
from __future__ import annotations

import jax.numpy as jnp

from .flash_attention import flash_attention
from .ref import flash_attention_ref  # noqa: F401


def flash_attention_gqa(q, k, v, causal=True, interpret=True,
                        q_block=128, kv_block=128):
    """q: (B, S, N, dh); k/v: (B, S, Kh, dh) → (B, S, N·dh)."""
    B, S, N, dh = q.shape
    Kh = k.shape[2]
    G = N // Kh
    qf = q.transpose(0, 2, 1, 3).reshape(B * N, S, dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * N, S, dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * N, S, dh)
    out = flash_attention(qf, kf, vf, causal=causal, interpret=interpret,
                          q_block=q_block, kv_block=kv_block)
    return out.reshape(B, N, S, dh).transpose(0, 2, 1, 3).reshape(B, S, N * dh)
