"""Pallas TPU kernel: multi-channel segment-⊕ (dense-key segment sum).

The serving scorer's one-pass SumProd evaluation is dominated by the
join-tree edge messages ``msg[key, c] = Σ_{r : ids[r]=key} vals[r, c]``
over stacked leaf channels c.  Like count_sketch, a random scatter-add
serializes through scalar memory on TPU, so the kernel reformulates each
row tile's contribution as a **one-hot × value matmul** on the MXU:

    msg_tile[key, c] = Σ_r onehot(ids[r])[key] · vals[r, c]
                     = onehot_matrixᵀ · vals_tile

The grid walks row tiles; the (n_keys, channels) output block is
revisited across grid steps and accumulated in place (Pallas guarantees
sequential grid order on TPU, so the read-modify-write is safe).
VMEM: vals tile (nt × c) + one-hot (nt × n_keys) f32 + output block
(n_keys × c) — ≤ ~2 MB at nt=256, n_keys=2048, c=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(v_ref, i_ref, o_ref, *, n_keys: int):
    t = pl.program_id(0)
    v = v_ref[...]                                   # (nt, c)
    ids = i_ref[...]                                 # (nt,)
    oh = (ids[:, None] == jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], n_keys), 1))
    contrib = jnp.dot(
        oh.astype(jnp.float32).T, v,
        preferred_element_type=jnp.float32,
    )                                                # (n_keys, c)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += contrib.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_keys", "tile", "interpret"))
def segment_sum_2d(vals: jnp.ndarray, ids: jnp.ndarray, n_keys: int,
                   tile: int = 256, interpret: bool = True) -> jnp.ndarray:
    """vals: (n, c) f32, ids: (n,) int32 in [0, n_keys) → (n_keys, c).

    n is padded to the tile; padded rows carry value 0 so they contribute
    nothing regardless of their (zero-padded) key.
    """
    n, c = vals.shape
    pad = (-n) % tile
    if pad:
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
        ids = jnp.pad(ids, (0, pad))
    grid = (vals.shape[0] // tile,)
    return pl.pallas_call(
        functools.partial(_kernel, n_keys=n_keys),
        out_shape=jax.ShapeDtypeStruct((n_keys, c), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, c), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n_keys, c), lambda i: (0, 0)),
        interpret=interpret,
    )(vals.astype(jnp.float32), ids.astype(jnp.int32))
