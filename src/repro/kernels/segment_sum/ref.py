"""Pure-jnp oracle for the segment-sum kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(vals: jnp.ndarray, ids: jnp.ndarray, n_keys: int) -> jnp.ndarray:
    return jax.ops.segment_sum(vals, ids, num_segments=n_keys)
