"""jit'd wrapper: semiring-facing segment-⊕ entry point.

Handles the 1-D (Arithmetic) and 2-D (Channels) value layouts the
SumProd engine produces; higher-rank (complex/poly) values fall back to
the jnp oracle — the kernel targets the serving scorer's stacked-leaf
Channels evaluation.
"""
from __future__ import annotations

import jax.numpy as jnp

from .ref import segment_sum_ref  # noqa: F401
from .segment_sum import segment_sum_2d


def segment_sum_op(vals: jnp.ndarray, ids: jnp.ndarray, n_keys: int,
                   interpret: bool = True) -> jnp.ndarray:
    if vals.ndim == 1:
        return segment_sum_2d(vals[:, None], ids, n_keys, interpret=interpret)[:, 0]
    if vals.ndim == 2 and vals.dtype in (jnp.float32, jnp.bfloat16):
        return segment_sum_2d(vals, ids, n_keys, interpret=interpret)
    return segment_sum_ref(vals, ids, n_keys)
