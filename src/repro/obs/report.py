"""Schema-versioned ``BENCH_<name>.json`` perf-trajectory reports.

Every ``benchmarks/bench_*.py`` smoke funnels its result rows and
headline metrics through a :class:`BenchReport`, which stamps a machine
/config fingerprint, the process metrics snapshot, and (when tracing
was on) a per-span rollup, then writes ``BENCH_<name>.json`` at the
repo root.  Committing those files makes the perf trajectory reviewable
PR-over-PR, and ``benchmarks/report.py --check`` gates the nightly job
on them: missing file, schema violation, or a pinned metric regressing
>2× versus the committed baseline all fail.

Schema v1 (validated by :func:`validate_bench`):

    {"schema_version": 1, "bench": str, "fingerprint": {...},
     "config": {...}, "metrics": {str: number}, "rows": [dict, ...],
     "metrics_snapshot": {...}?, "span_rollup": {...}?}

``metrics`` holds the headline scalars baselines pin (count-derived
ratios preferred over wall-clock — they are scheduler-noise free).
"""
from __future__ import annotations

import json
import os
import platform
from typing import Dict, List, Optional

from .metrics import get_registry
from .trace import get_tracer, tracing_enabled

__all__ = ["BenchReport", "fingerprint", "validate_bench", "bench_path"]

SCHEMA_VERSION = 1


def fingerprint() -> dict:
    """Machine/config identity a report was measured on — enough to
    judge whether two trajectory points are comparable."""
    fp = {
        "platform": platform.platform(),
        "python": platform.python_version(),
        "cpus": os.cpu_count(),
    }
    try:
        import jax
        fp["jax"] = jax.__version__
        fp["device"] = jax.devices()[0].device_kind
        fp["backend"] = jax.default_backend()
        # device-tagged entries: forced-host-device CI legs and real
        # hardware runs both land with their parallel width recorded
        fp["device_count"] = jax.device_count()
        try:
            from ..distributed import spmd
            mesh = spmd.mesh_fingerprint()
            if mesh is not None:        # active data mesh at report time
                fp["mesh"] = mesh
        except Exception:
            pass
    except Exception:
        fp["jax"] = None
    return fp


def bench_path(name: str, out_dir: Optional[str] = None) -> str:
    """Canonical location of ``BENCH_<name>.json`` — the repo root by
    default (override with ``REPRO_BENCH_DIR`` for scratch runs)."""
    if out_dir is None:
        out_dir = os.environ.get("REPRO_BENCH_DIR") or os.getcwd()
    return os.path.join(out_dir, f"BENCH_{name}.json")


class BenchReport:
    """Accumulates one benchmark's rows + headline metrics, then writes
    the schema-versioned JSON artifact."""

    def __init__(self, name: str, config: Optional[dict] = None):
        self.name = name
        self.config = dict(config or {})
        self.rows: List[dict] = []
        self.metrics: Dict[str, float] = {}

    def add_rows(self, rows: List[dict]) -> "BenchReport":
        self.rows.extend(rows)
        return self

    def set_metric(self, key: str, value) -> "BenchReport":
        self.metrics[key] = float(value)
        return self

    def to_dict(self) -> dict:
        doc = {
            "schema_version": SCHEMA_VERSION,
            "bench": self.name,
            "fingerprint": fingerprint(),
            "config": self.config,
            "metrics": self.metrics,
            "rows": self.rows,
            "metrics_snapshot": get_registry().snapshot(),
        }
        if tracing_enabled():
            doc["span_rollup"] = get_tracer().rollup()
        return doc

    def write(self, out_dir: Optional[str] = None) -> str:
        """Write ``BENCH_<name>.json`` (and, when tracing is enabled,
        the raw span sink ``TRACE_<name>.jsonl`` beside it)."""
        path = bench_path(self.name, out_dir)
        doc = self.to_dict()
        errors = validate_bench(doc)
        if errors:                    # a writer bug must fail loudly, not
            raise ValueError(errors)  # poison the committed trajectory
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True, default=_jsonable)
            f.write("\n")
        if tracing_enabled():
            get_tracer().dump_jsonl(
                os.path.join(os.path.dirname(path),
                             f"TRACE_{self.name}.jsonl"))
        return path


def _jsonable(o):
    try:
        import numpy as np
        if isinstance(o, np.generic):
            return o.item()
    except Exception:
        pass
    raise TypeError(f"not JSON-serializable: {type(o).__name__}")


def validate_bench(doc: dict) -> List[str]:
    """Schema-v1 structural check; returns human-readable violations
    (empty list == valid)."""
    errs = []
    if not isinstance(doc, dict):
        return ["report is not an object"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"schema_version must be {SCHEMA_VERSION}, "
                    f"got {doc.get('schema_version')!r}")
    if not isinstance(doc.get("bench"), str) or not doc.get("bench"):
        errs.append("bench must be a non-empty string")
    if not isinstance(doc.get("fingerprint"), dict):
        errs.append("fingerprint must be an object")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict):
        errs.append("metrics must be an object")
    else:
        for k, v in metrics.items():
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                errs.append(f"metrics[{k!r}] must be a number, got {v!r}")
    rows = doc.get("rows")
    if not isinstance(rows, list) or any(not isinstance(r, dict) for r in rows):
        errs.append("rows must be a list of objects")
    return errs
