"""Thread-safe named metrics: counters, gauges, log-bucketed histograms.

A :class:`MetricsRegistry` is a flat namespace of named series.  The
instruments are deliberately tiny — an ``inc``/``set``/``observe`` is a
lock acquire plus an integer/dict update, cheap enough to live on the
hot paths they measure (`QueryCounter` bumps from jitted callbacks and
benchmark threads, the serving batcher's per-request latencies).

Snapshots are plain JSON-able dicts, so three derived operations cover
every reporting need:

- ``registry.snapshot()``  — point-in-time values/summaries;
- ``diff_snapshots(a, b)`` — work done *between* two snapshots
  (counters/histogram buckets subtract; gauges keep the later value);
- ``merge_snapshots(a, b)`` — combine series from parallel actors
  (counters/buckets add, min/max widen).

Histograms are log-bucketed (``RES`` sub-buckets per octave, ~9%
relative width), so quantile summaries (p50/p90/p99) cost O(#buckets)
and merging is exact.  No dependencies beyond the stdlib.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Optional

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "get_registry", "reset_registry", "diff_snapshots", "merge_snapshots",
    "format_summary_table",
]


class Counter:
    """Monotonic accumulator; ``inc`` is safe from any thread."""

    def __init__(self, name: str = ""):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += int(n)

    @property
    def value(self) -> int:
        return self._value

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self._value}


class Gauge:
    """Last-write-wins scalar (drift level, resident cache size, …)."""

    def __init__(self, name: str = ""):
        self.name = name
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self._value}


class Histogram:
    """Log-bucketed distribution with mergeable quantile summaries.

    Bucket ``i`` covers ``[2^(i/RES), 2^((i+1)/RES))`` — a geometric
    grid with ``RES`` sub-buckets per octave, so any quantile estimate
    is within one bucket width (~``2^(1/RES)−1`` relative) of exact.
    Non-positive observations land in a dedicated underflow bucket and
    only influence count/sum/min.
    """

    RES = 8                      # sub-buckets per power of two (~9% width)
    _UNDER = -(10 ** 9)          # bucket index for values ≤ 0

    def __init__(self, name: str = ""):
        self.name = name
        self._lock = threading.Lock()
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _index(self, v: float) -> int:
        if v <= 0.0:
            return self._UNDER
        return math.floor(math.log2(v) * self.RES)

    def observe(self, v: float) -> None:
        v = float(v)
        i = self._index(v)
        with self._lock:
            self.buckets[i] = self.buckets.get(i, 0) + 1
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)

    # ------------------------------------------------------------ queries --
    def quantile(self, q: float) -> float:
        """Estimate the ``q``-quantile from the bucket histogram (the
        geometric bucket midpoint, clamped to the observed min/max)."""
        if self.count == 0:
            return math.nan
        rank = q * (self.count - 1)
        seen = 0
        for i in sorted(self.buckets):
            seen += self.buckets[i]
            if seen > rank:
                if i == self._UNDER:
                    return self.min
                mid = 2.0 ** ((i + 0.5) / self.RES)
                return min(max(mid, self.min), self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def summary(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "mean": self.mean if self.count else None,
            "p50": self.quantile(0.50) if self.count else None,
            "p90": self.quantile(0.90) if self.count else None,
            "p99": self.quantile(0.99) if self.count else None,
        }

    def snapshot(self) -> dict:
        return {"type": "histogram", **self.summary(),
                "buckets": {str(k): v for k, v in self.buckets.items()}}

    def merge(self, other: "Histogram") -> "Histogram":
        """In-place ⊎: bucket-wise add (exact — the grid is shared)."""
        with self._lock:
            for i, c in other.buckets.items():
                self.buckets[i] = self.buckets.get(i, 0) + c
            self.count += other.count
            self.sum += other.sum
            self.min = min(self.min, other.min)
            self.max = max(self.max, other.max)
        return self


class MetricsRegistry:
    """Named series with get-or-create semantics, safe across threads."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self):
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> Dict[str, dict]:
        """JSON-able {name: instrument snapshot} for every series."""
        with self._lock:
            items = list(self._metrics.items())
        return {name: m.snapshot() for name, m in items}

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


# ------------------------------------------------------ snapshot algebra --
def _diff_hist(a: dict, b: dict) -> dict:
    """Histogram work b−a: bucket/count/sum subtract; min/max/quantiles
    cannot be recovered for the window, so they are re-estimated from
    the differenced buckets."""
    buckets = dict(b.get("buckets", {}))
    for k, v in (a.get("buckets") or {}).items():
        buckets[k] = buckets.get(k, 0) - v
        if buckets[k] <= 0:
            buckets.pop(k)
    count = b["count"] - a["count"]
    out = {
        "type": "histogram",
        "count": count,
        "sum": b["sum"] - a["sum"],
        "min": None, "max": None,
        "mean": (b["sum"] - a["sum"]) / count if count else None,
        "p50": None, "p90": None, "p99": None,
        "buckets": buckets,
    }
    if count > 0 and buckets:
        h = Histogram()
        h.buckets = {int(k): v for k, v in buckets.items()}
        h.count = count
        h.sum = out["sum"]
        idx = sorted(h.buckets)
        h.min = 2.0 ** (idx[0] / h.RES) if idx[0] != h._UNDER else 0.0
        h.max = 2.0 ** ((idx[-1] + 1) / h.RES)
        out.update(p50=h.quantile(.5), p90=h.quantile(.9), p99=h.quantile(.99),
                   min=h.min, max=h.max)
    return out


def diff_snapshots(before: Dict[str, dict], after: Dict[str, dict]) -> Dict[str, dict]:
    """Work done between two snapshots of the SAME registry."""
    out = {}
    for name, b in after.items():
        a = before.get(name)
        if a is None or a["type"] != b["type"]:
            out[name] = dict(b)
        elif b["type"] == "counter":
            out[name] = {"type": "counter", "value": b["value"] - a["value"]}
        elif b["type"] == "gauge":
            out[name] = dict(b)
        else:
            out[name] = _diff_hist(a, b)
    return out


def merge_snapshots(a: Dict[str, dict], b: Dict[str, dict]) -> Dict[str, dict]:
    """⊎ of snapshots from parallel actors (counters/buckets add)."""
    out = {k: dict(v) for k, v in a.items()}
    for name, m in b.items():
        cur = out.get(name)
        if cur is None or cur["type"] != m["type"]:
            out[name] = dict(m)
        elif m["type"] == "counter":
            cur["value"] += m["value"]
        elif m["type"] == "gauge":
            cur["value"] = m["value"]
        else:
            h = Histogram()
            for src in (cur, m):
                for k, v in (src.get("buckets") or {}).items():
                    h.buckets[int(k)] = h.buckets.get(int(k), 0) + v
            h.count = cur["count"] + m["count"]
            h.sum = cur["sum"] + m["sum"]
            mins = [x["min"] for x in (cur, m) if x.get("min") is not None]
            maxs = [x["max"] for x in (cur, m) if x.get("max") is not None]
            h.min = min(mins) if mins else math.inf
            h.max = max(maxs) if maxs else -math.inf
            out[name] = h.snapshot()
    return out


def format_summary_table(snapshot: Dict[str, dict], title: str = "metrics") -> str:
    """One-screen fixed-width rendering of a snapshot — what the launch
    CLIs print on exit instead of ad-hoc prints."""
    lines = [f"── {title} " + "─" * max(0, 62 - len(title))]
    width = max([len(n) for n in snapshot] or [8])
    for name in sorted(snapshot):
        m = snapshot[name]
        if m["type"] == "counter":
            lines.append(f"{name:<{width}}  {m['value']:>12,}")
        elif m["type"] == "gauge":
            lines.append(f"{name:<{width}}  {m['value']:>12.4g}")
        else:
            if not m["count"]:
                continue
            lines.append(
                f"{name:<{width}}  n={m['count']:<8,} "
                f"mean={m['mean']:.3g} p50={m['p50']:.3g} "
                f"p90={m['p90']:.3g} p99={m['p99']:.3g} max={m['max']:.3g}")
    lines.append("─" * 64)
    return "\n".join(lines)


# ---------------------------------------------------------- process-wide --
_global_registry = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry (instrumented subsystems mirror
    their per-instance accounting into it as named series)."""
    return _global_registry


def reset_registry() -> None:
    """Clear the process-wide registry (tests and benchmark phases)."""
    _global_registry.clear()
