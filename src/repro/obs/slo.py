"""Declarative SLOs evaluated with multi-window burn rates.

An :class:`SLOObjective` states what "good" means for one dimension of
the serve/ingest loop:

- ``latency``    — a fraction ``objective`` of requests must finish
  within ``target`` milliseconds (e.g. p99 ≤ 50ms ⇒ target=50,
  objective=0.99);
- ``error_rate`` — the failure fraction must stay below ``target``;
- ``staleness``  — the wall-clock lag of the served ``data_version``
  behind applied deltas must stay below ``target`` seconds.

The :class:`SLOMonitor` turns the event stream (per-request latencies,
error/ok outcomes, a staleness gauge) into *burn rates*: how fast the
error budget is being consumed relative to the allowed rate (burn 1.0 =
exactly on budget).  Following the SRE multi-window rule, each
objective is judged over a FAST and a SLOW window — the fast window
reacts to an incident in seconds, the slow window keeps a transient
blip from flapping the state — and both must burn hot before the
objective degrades.  Event windows are bucketed rings, so memory is
O(buckets) regardless of traffic.

The aggregate state (worst objective) is one of ``healthy`` /
``degraded`` / ``unhealthy``: ``/healthz`` reports it (503 on
unhealthy) and the service batcher consumes it as an overload signal —
degraded shortens the coalescing window, unhealthy sheds new
admissions.  This is the hook the ROADMAP's admission-control /
backpressure item attaches to.

``parse_slo_spec`` accepts the CLI grammar::

    latency=50ms@0.99,errors=0.01,staleness=5s
"""
from __future__ import annotations

import dataclasses
import math
import re
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence

from .metrics import MetricsRegistry, get_registry

__all__ = ["SLOObjective", "SLOMonitor", "parse_slo_spec",
           "HEALTHY", "DEGRADED", "UNHEALTHY"]

HEALTHY, DEGRADED, UNHEALTHY = "healthy", "degraded", "unhealthy"
_STATE_RANK = {HEALTHY: 0, DEGRADED: 1, UNHEALTHY: 2}
KINDS = ("latency", "error_rate", "staleness")


@dataclasses.dataclass(frozen=True)
class SLOObjective:
    """One service-level objective (see module docstring for kinds)."""

    name: str
    kind: str                    # "latency" | "error_rate" | "staleness"
    target: float                # ms (latency) / fraction / seconds
    objective: float = 0.99     # good-fraction required (latency kind only)
    # graceful-degradation cap: this objective can pull the aggregate
    # state to DEGRADED but never UNHEALTHY.  Used by follower replicas:
    # a dead writer makes served data arbitrarily stale, and the right
    # behavior is "serve stale, report degraded" — not shedding the only
    # traffic the replica exists to absorb.
    degrade_only: bool = False

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown SLO kind {self.kind!r} (want {KINDS})")
        if self.target <= 0:
            raise ValueError(f"SLO target must be positive, got {self.target}")
        if not 0.0 < self.objective < 1.0:
            raise ValueError(
                f"objective fraction must be in (0, 1), got {self.objective}")

    @property
    def budget(self) -> float:
        """Allowed bad-event fraction (the error budget rate)."""
        if self.kind == "latency":
            return 1.0 - self.objective
        if self.kind == "error_rate":
            return self.target
        return 1.0                       # staleness burns target-relative


class _Window:
    """Rolling (good, bad) counts over a horizon, in coarse buckets."""

    def __init__(self, horizon_s: float, n_buckets: int = 20):
        self.horizon_s = horizon_s
        self.width = horizon_s / n_buckets
        self.n_buckets = n_buckets
        self._d: deque = deque()         # (bucket_idx, [good, bad])

    def add(self, good: int, bad: int, now: float) -> None:
        idx = int(now / self.width)
        if self._d and self._d[-1][0] == idx:
            cell = self._d[-1][1]
            cell[0] += good
            cell[1] += bad
        else:
            self._d.append((idx, [good, bad]))
        self._evict(idx)

    def _evict(self, idx: int) -> None:
        floor = idx - self.n_buckets
        while self._d and self._d[0][0] <= floor:
            self._d.popleft()

    def totals(self, now: float):
        self._evict(int(now / self.width))
        good = sum(c[0] for _, c in self._d)
        bad = sum(c[1] for _, c in self._d)
        return good, bad


class SLOMonitor:
    """Multi-window burn-rate evaluation over a set of objectives.

    ``degraded_burn`` / ``unhealthy_burn`` are the burn-rate thresholds
    BOTH windows must exceed; ``clock`` is injectable so tests can march
    time deterministically.  Lifetime good/total tallies are kept per
    objective for SLO-compliance reporting (``compliance()``), and every
    ``evaluate()`` mirrors the burn rates into the registry as
    ``slo.<name>.burn_fast`` / ``.burn_slow`` gauges plus a numeric
    ``slo.state`` (0 healthy / 1 degraded / 2 unhealthy)."""

    def __init__(
        self,
        objectives: Sequence[SLOObjective],
        fast_window_s: float = 60.0,
        slow_window_s: float = 600.0,
        degraded_burn: float = 1.0,
        unhealthy_burn: float = 6.0,
        clock: Callable[[], float] = time.monotonic,
        registry: Optional[MetricsRegistry] = None,
        state_ttl_s: float = 0.05,
    ):
        if fast_window_s >= slow_window_s:
            raise ValueError("fast window must be shorter than the slow one")
        self.objectives = {o.name: o for o in objectives}
        if len(self.objectives) != len(objectives):
            raise ValueError("duplicate objective names")
        self.degraded_burn = degraded_burn
        self.unhealthy_burn = unhealthy_burn
        self.clock = clock
        self.registry = registry if registry is not None else get_registry()
        self._win: Dict[str, Dict[str, _Window]] = {
            o.name: {"fast": _Window(fast_window_s),
                     "slow": _Window(slow_window_s)}
            for o in objectives
        }
        self._life: Dict[str, List[int]] = {o.name: [0, 0]  # [good, bad]
                                            for o in objectives}
        self._staleness_s = 0.0
        self._state_ttl = state_ttl_s
        self._state_cache = (None, -math.inf)   # (state, eval time)

    # ------------------------------------------------------------ recording --
    def _add(self, name: str, good: bool) -> None:
        now = self.clock()
        g, b = (1, 0) if good else (0, 1)
        for w in self._win[name].values():
            w.add(g, b, now)
        life = self._life[name]
        life[0] += g
        life[1] += b

    def record_latency(self, ms: float) -> None:
        """One finished request's end-to-end latency (latency objectives
        judge it against their threshold)."""
        for o in self.objectives.values():
            if o.kind == "latency":
                self._add(o.name, ms <= o.target)

    def record_request(self, error: bool = False) -> None:
        """One request outcome for the error-rate objectives."""
        for o in self.objectives.values():
            if o.kind == "error_rate":
                self._add(o.name, not error)

    def set_staleness(self, seconds: float) -> None:
        """Current served-data staleness (wall-clock lag behind applied
        deltas); gauge semantics — the latest value is what burns."""
        self._staleness_s = max(0.0, float(seconds))

    # ----------------------------------------------------------- evaluation --
    def _burn(self, o: SLOObjective, win: _Window, now: float) -> float:
        if o.kind == "staleness":
            return self._staleness_s / o.target
        good, bad = win.totals(now)
        total = good + bad
        if total == 0:
            return 0.0                   # no traffic consumes no budget
        return (bad / total) / max(o.budget, 1e-9)

    def evaluate(self) -> dict:
        """Full report: per-objective burn rates + aggregate state."""
        now = self.clock()
        reg = self.registry
        out: Dict[str, dict] = {}
        worst = HEALTHY
        for name, o in self.objectives.items():
            fast = self._burn(o, self._win[name]["fast"], now)
            slow = self._burn(o, self._win[name]["slow"], now)
            floor = min(fast, slow)      # both windows must burn hot
            state = (UNHEALTHY if floor >= self.unhealthy_burn else
                     DEGRADED if floor >= self.degraded_burn else HEALTHY)
            if o.degrade_only and state == UNHEALTHY:
                state = DEGRADED         # serve stale, never shed

            if _STATE_RANK[state] > _STATE_RANK[worst]:
                worst = state
            good, bad = self._life[name]
            out[name] = {
                "kind": o.kind, "target": o.target, "objective": o.objective,
                "burn_fast": round(fast, 4), "burn_slow": round(slow, 4),
                "state": state,
                "good": good, "bad": bad,
                "compliance": good / (good + bad) if good + bad else None,
            }
            reg.gauge(f"slo.{name}.burn_fast").set(fast)
            reg.gauge(f"slo.{name}.burn_slow").set(slow)
        reg.gauge("slo.state").set(_STATE_RANK[worst])
        self._state_cache = (worst, now)
        return {"state": worst, "staleness_s": round(self._staleness_s, 6),
                "objectives": out}

    def state(self) -> str:
        """Aggregate state, memoized for ``state_ttl_s`` so per-request
        admission checks don't re-walk the windows."""
        cached, t = self._state_cache
        if cached is not None and self.clock() - t < self._state_ttl:
            return cached
        return self.evaluate()["state"]

    def compliance(self, name: str) -> Optional[float]:
        """Lifetime good fraction for one objective (None = no events)."""
        good, bad = self._life[name]
        return good / (good + bad) if good + bad else None


# ----------------------------------------------------------------- parsing --
_UNIT = {"ms": 1.0, "s": 1000.0, "us": 1e-3, "": None}
_TERM = re.compile(
    r"^(?P<kind>latency|errors|error_rate|staleness)"
    r"=(?P<value>[0-9.]+)(?P<unit>ms|us|s)?(?:@(?P<frac>0?\.[0-9]+))?$")


def parse_slo_spec(spec: str) -> List[SLOObjective]:
    """CLI grammar → objectives: comma-separated ``kind=value[@frac]``.

    ``latency=50ms@0.99`` — 99% of requests within 50ms (unit defaults
    to ms); ``errors=0.01`` — error rate below 1%; ``staleness=5s`` —
    served data at most 5s behind applied deltas (unit defaults to s).
    """
    out: List[SLOObjective] = []
    for term in filter(None, (t.strip() for t in spec.split(","))):
        m = _TERM.match(term)
        if m is None:
            raise ValueError(
                f"bad SLO term {term!r} — want kind=value[@frac] with kind "
                f"in latency/errors/staleness, e.g. 'latency=50ms@0.99'")
        kind, value, unit = m["kind"], float(m["value"]), m["unit"] or ""
        frac = float(m["frac"]) if m["frac"] else 0.99
        if kind == "latency":
            ms = value * (_UNIT[unit] or 1.0)
            out.append(SLOObjective("latency", "latency", ms, objective=frac))
        elif kind in ("errors", "error_rate"):
            if unit:
                raise ValueError(f"error rate takes a bare fraction: {term!r}")
            out.append(SLOObjective("errors", "error_rate", value))
        else:
            s = value * ((_UNIT[unit] or 1000.0) / 1000.0)
            out.append(SLOObjective("staleness", "staleness", s))
    if not out:
        raise ValueError(f"empty SLO spec {spec!r}")
    return out
