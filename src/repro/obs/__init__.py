"""Unified observability: tracing spans, named metrics, bench reports.

Zero-dependency (stdlib only; jax touched lazily and optionally).  The
three pieces every subsystem reports through:

- :mod:`.trace` — nestable ``span("name", **attrs)`` context managers
  over the SumProd / boosting / serving hot paths, a process
  :class:`Tracer` with JSONL + Chrome-trace (Perfetto) export,
  ``jax.profiler`` annotation passthrough, and :func:`fence` for
  explicit ``block_until_ready`` attribution.  Default-off: disabled
  spans are a shared no-op context manager.
- :mod:`.metrics` — a thread-safe :class:`MetricsRegistry` of counters,
  gauges, and log-bucketed histograms with snapshot/diff/merge
  semantics; ``QueryCounter``, ``MessageCache``, the serving LRU cache
  and ``ServiceStats`` all mirror into it as named series.
- :mod:`.report` — :class:`BenchReport` writes schema-versioned
  ``BENCH_<name>.json`` artifacts (machine fingerprint, metric
  snapshots, span rollups) so the perf trajectory is tracked
  PR-over-PR; ``benchmarks/report.py --check`` gates CI on them.

Live telemetry (this layer observing a RUNNING system, not just a
finished one):

- :mod:`.flight` — :class:`FlightRecorder`: always-on ring-buffer
  tracing (O(1) memory) with latency/error-triggered Perfetto dumps;
- :mod:`.exposition` — Prometheus/JSON rendering of any registry
  snapshot, the :class:`TelemetryServer` HTTP endpoints
  (``/metricsz`` ``/healthz`` ``/statusz`` ``/tracez``), and the
  :class:`PeriodicSampler` JSONL time series;
- :mod:`.slo` — declarative :class:`SLOObjective`s evaluated by an
  :class:`SLOMonitor` with multi-window burn rates into a
  healthy/degraded/unhealthy state the service consumes as an
  overload signal.
"""
from .exposition import (
    PeriodicSampler, TelemetryServer, render_json, render_prometheus,
)
from .flight import FlightRecorder
from .metrics import (
    Counter, Gauge, Histogram, MetricsRegistry, diff_snapshots,
    format_summary_table, get_registry, merge_snapshots, reset_registry,
)
from .report import BenchReport, bench_path, fingerprint, validate_bench
from .slo import SLOMonitor, SLOObjective, parse_slo_spec
from .trace import (
    Tracer, disable_tracing, enable_tracing, fence, get_tracer, span,
    tracing_enabled,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "diff_snapshots", "merge_snapshots", "format_summary_table",
    "get_registry", "reset_registry",
    "BenchReport", "bench_path", "fingerprint", "validate_bench",
    "Tracer", "span", "fence", "enable_tracing", "disable_tracing",
    "tracing_enabled", "get_tracer",
    "FlightRecorder",
    "TelemetryServer", "PeriodicSampler", "render_prometheus", "render_json",
    "SLOMonitor", "SLOObjective", "parse_slo_spec",
]
