"""Flight recorder: always-on ring-buffer tracing with trigger dumps.

Full tracing (PR 6) is an enable → run → dump workflow: the
:class:`~repro.obs.trace.Tracer` buffers every span unboundedly, which a
long-running serve/ingest loop cannot afford.  The flight recorder runs
the SAME tracer in ring mode — the newest ``capacity`` spans are kept,
the oldest silently overwritten, O(1) memory forever — so the spans
surrounding an incident are always available without ever paying full
capture.

Dumps are *trigger based*: the hosting loop feeds per-request latencies
(:meth:`FlightRecorder.observe_latency`) and exceptions
(:meth:`FlightRecorder.observe_error`); when a latency crosses the
threshold or an error fires, the recorder snapshots the ring to a
Perfetto-loadable Chrome-trace file (``FLIGHT_<name>_<seq>.json``) with
an instant event marking what tripped it.  A cooldown and a dump budget
keep a sustained incident from writing the same story to disk hundreds
of times; suppressed triggers are still counted
(``flight.suppressed``), so the metrics tell you the incident kept
going after the first dump.

Typical wiring (the serving CLIs do exactly this)::

    flight = FlightRecorder(capacity=4096, latency_trigger_ms=50.0)
    flight.start()                       # ring-mode tracing, always on
    service = RelationalScoringService(..., flight=flight)
    # ... tail-latency spike → FLIGHT_serving_000.json appears, holding
    # the last 4096 spans around the offending batch
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import List, Optional

from .metrics import get_registry
from .trace import Tracer, get_tracer

__all__ = ["FlightRecorder"]


class FlightRecorder:
    """Bounded always-on tracing plus threshold/error-triggered dumps."""

    def __init__(
        self,
        capacity: int = 4096,
        out_dir: str = ".",
        name: str = "flight",
        latency_trigger_ms: Optional[float] = None,
        error_trigger: bool = True,
        cooldown_s: float = 30.0,
        max_dumps: int = 16,
        tracer: Optional[Tracer] = None,
    ):
        self.capacity = int(capacity)
        self.out_dir = out_dir
        self.name = name
        self.latency_trigger_ms = latency_trigger_ms
        self.error_trigger = error_trigger
        self.cooldown_s = cooldown_s
        self.max_dumps = max_dumps
        self.tracer = tracer if tracer is not None else get_tracer()
        self.dumps: List[dict] = []          # {path, reason, n_events, ts}
        self.suppressed = 0                  # triggers inside cooldown/budget
        self._lock = threading.Lock()
        self._last_dump_t: Optional[float] = None
        self._active = False

    # -------------------------------------------------------------- control --
    def start(self) -> "FlightRecorder":
        """Switch the tracer into ring mode and enable recording.  Events
        already buffered are kept (newest-first if they overflow)."""
        self.tracer.set_ring(self.capacity)
        self.tracer.enabled = True
        self._active = True
        return self

    def stop(self) -> "FlightRecorder":
        """Stop recording and return the tracer to the unbounded sink
        (the ring's current contents are preserved for a final dump)."""
        self._active = False
        self.tracer.enabled = False
        self.tracer.set_unbounded()
        return self

    @property
    def active(self) -> bool:
        return self._active

    # ------------------------------------------------------------- triggers --
    def observe_latency(self, ms: float, **attrs) -> Optional[str]:
        """Feed one request/batch latency; dumps when it crosses the
        threshold.  Returns the dump path when one was written."""
        if self.latency_trigger_ms is None or ms < self.latency_trigger_ms:
            return None
        return self.trigger(
            f"latency {ms:.1f}ms >= trigger {self.latency_trigger_ms:g}ms",
            latency_ms=round(float(ms), 3), **attrs)

    def observe_error(self, exc: BaseException, **attrs) -> Optional[str]:
        """Feed one exception; dumps unless error triggering is off."""
        if not self.error_trigger:
            return None
        return self.trigger(f"error {type(exc).__name__}: {exc}",
                            error=type(exc).__name__, **attrs)

    def trigger(self, reason: str, **attrs) -> Optional[str]:
        """Snapshot the ring to a Perfetto-loadable file (rate-limited).

        Thread-safe; returns None when suppressed by the cooldown or the
        dump budget (counted in ``flight.suppressed``)."""
        reg = get_registry()
        now = time.perf_counter()
        with self._lock:
            blocked = (
                len(self.dumps) >= self.max_dumps
                or (self._last_dump_t is not None
                    and now - self._last_dump_t < self.cooldown_s)
            )
            if blocked:
                self.suppressed += 1
                reg.counter("flight.suppressed").inc()
                return None
            self._last_dump_t = now
            seq = len(self.dumps)
            rec = {"path": None, "reason": reason, "n_events": 0,
                   "ts": time.time()}
            self.dumps.append(rec)          # reserve the sequence slot
        os.makedirs(self.out_dir, exist_ok=True)
        path = os.path.join(self.out_dir, f"FLIGHT_{self.name}_{seq:03d}.json")
        doc = self.tracer.to_chrome_trace()
        # instant event marking the trigger, so the dump is self-describing
        # on the Perfetto timeline ("i" = instant, "s": "g" = global scope)
        doc["traceEvents"].append({
            "name": "flight.trigger", "ph": "i", "s": "g", "cat": "obs",
            "ts": round((now - self.tracer._t0) * 1e6, 3),
            "pid": 1, "tid": 0,
            "args": {"reason": reason, **attrs},
        })
        with open(path, "w") as f:
            json.dump(doc, f)
        rec["path"] = path
        rec["n_events"] = len(doc["traceEvents"])
        reg.counter("flight.dumps").inc()
        return path

    # -------------------------------------------------------------- queries --
    def snapshot(self) -> List[dict]:
        """The ring's current events, oldest first (for ``/tracez``)."""
        with self.tracer._lock:
            return list(self.tracer.events)

    def status(self) -> dict:
        """JSON-able summary for ``/statusz`` and exit reports."""
        return {
            "active": self._active,
            "capacity": self.capacity,
            "buffered": len(self.tracer.events),
            "latency_trigger_ms": self.latency_trigger_ms,
            "error_trigger": self.error_trigger,
            "dumps": [dict(d) for d in self.dumps],
            "suppressed": self.suppressed,
        }
