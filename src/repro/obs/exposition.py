"""Metrics exposition: Prometheus/JSON rendering, HTTP endpoints, sampler.

Everything here is dependency-free (stdlib asyncio + json), so the
serving loop exposes live telemetry without pulling a web framework
into the tree:

- :func:`render_prometheus` / :func:`render_json` — turn any
  :meth:`MetricsRegistry.snapshot` dict into Prometheus text format
  (counters/gauges verbatim, histograms as summaries with
  p50/p90/p99 quantile labels) or pretty JSON;
- :class:`TelemetryServer` — a minimal asyncio HTTP listener serving

  =============  =====================================================
  ``/metricsz``  Prometheus text (``?format=json`` for the raw snapshot)
  ``/healthz``   SLO burn-rate state — 200 healthy/degraded, 503 unhealthy
  ``/statusz``   uptime, host-provided status dict, SLO + flight summary
  ``/tracez``    newest ``?n=`` spans from the tracer/flight ring (JSON)
  =============  =====================================================

  It attaches to an already-running asyncio loop (``await start()``,
  the scoring service's world) or hosts its own loop in a daemon
  thread (``start_in_thread()``, the synchronous delta/retrain
  drivers' world).  Port 0 binds an ephemeral port; the bound port is
  published on ``self.port``.
- :class:`PeriodicSampler` — appends timestamped snapshot *deltas* to a
  JSONL time series, so a whole run's trajectory (qps, p99, cache hit
  rate, ``ivm.deltas``, drift) can be plotted rather than only its
  endpoint.
"""
from __future__ import annotations

import asyncio
import json
import re
import threading
import time
from typing import Callable, Dict, List, Optional
from urllib.parse import parse_qs, urlsplit

from .metrics import MetricsRegistry, diff_snapshots, get_registry, merge_snapshots
from .trace import Tracer, get_tracer

__all__ = ["render_prometheus", "render_json", "TelemetryServer",
           "PeriodicSampler"]

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str, namespace: str) -> str:
    base = _NAME_OK.sub("_", name)
    return f"{namespace}_{base}" if namespace else base


def _prom_value(v) -> str:
    if v is None:
        return "NaN"
    f = float(v)
    if f != f:
        return "NaN"
    if f in (float("inf"), float("-inf")):
        return "+Inf" if f > 0 else "-Inf"
    return repr(f) if isinstance(v, float) else str(v)


def render_prometheus(snapshot: Dict[str, dict], namespace: str = "repro") -> str:
    """Prometheus text exposition (v0.0.4) of a registry snapshot.

    Counters and gauges render as their native types; histograms render
    as SUMMARIES (quantile-labelled series + ``_sum``/``_count``) —
    the log-bucket grid already gives exact mergeable quantiles, so
    re-encoding it as cumulative ``le`` buckets would only lose that.
    """
    lines: List[str] = []
    for name in sorted(snapshot):
        m = snapshot[name]
        pn = _prom_name(name, namespace)
        if m["type"] == "counter":
            lines.append(f"# TYPE {pn} counter")
            lines.append(f"{pn} {_prom_value(m['value'])}")
        elif m["type"] == "gauge":
            lines.append(f"# TYPE {pn} gauge")
            lines.append(f"{pn} {_prom_value(m['value'])}")
        else:
            lines.append(f"# TYPE {pn} summary")
            for q in ("0.5", "0.9", "0.99"):
                key = "p" + str(int(float(q) * 100))
                lines.append(f'{pn}{{quantile="{q}"}} '
                             f"{_prom_value(m.get(key))}")
            lines.append(f"{pn}_sum {_prom_value(m.get('sum', 0.0))}")
            lines.append(f"{pn}_count {_prom_value(m.get('count', 0))}")
    return "\n".join(lines) + "\n"


def render_json(snapshot: Dict[str, dict]) -> str:
    return json.dumps(snapshot, indent=1, sort_keys=True, default=str)


class TelemetryServer:
    """Dependency-free asyncio HTTP listener for the obs endpoints."""

    def __init__(
        self,
        registries: Optional[List[MetricsRegistry]] = None,
        slo=None,                       # SLOMonitor (obs/slo.py), optional
        flight=None,                    # FlightRecorder (obs/flight.py)
        tracer: Optional[Tracer] = None,
        status_fn: Optional[Callable[[], dict]] = None,
        host: str = "127.0.0.1",
        port: int = 0,
        namespace: str = "repro",
    ):
        self.registries = registries
        self.slo = slo
        self.flight = flight
        self.tracer = tracer if tracer is not None else get_tracer()
        self.status_fn = status_fn
        self.host = host
        self.port = port
        self.namespace = namespace
        self._server: Optional[asyncio.AbstractServer] = None
        self._t_start = time.time()
        self._thread: Optional[threading.Thread] = None
        self._thread_loop: Optional[asyncio.AbstractEventLoop] = None

    # ------------------------------------------------------------- snapshot --
    def snapshot(self) -> Dict[str, dict]:
        """⊎ of the process registry and every attached registry."""
        regs = self.registries if self.registries is not None else [get_registry()]
        snap: Dict[str, dict] = {}
        for r in regs:
            snap = merge_snapshots(snap, r.snapshot()) if snap else r.snapshot()
        return snap

    # ------------------------------------------------------------- lifecycle --
    async def start(self) -> "TelemetryServer":
        self._server = await asyncio.start_server(
            self._handle, host=self.host, port=self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._t_start = time.time()
        return self

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    def url(self, path: str = "/metricsz") -> str:
        return f"http://{self.host}:{self.port}{path}"

    def start_in_thread(self, timeout: float = 5.0) -> int:
        """Host the listener on its own daemon-thread event loop — for
        the synchronous drivers (stream_deltas / retrain_stream)."""
        started = threading.Event()

        def run():
            loop = asyncio.new_event_loop()
            self._thread_loop = loop
            asyncio.set_event_loop(loop)
            loop.run_until_complete(self.start())
            started.set()
            loop.run_forever()
            loop.run_until_complete(self.stop())
            loop.close()

        self._thread = threading.Thread(
            target=run, name="telemetry-server", daemon=True)
        self._thread.start()
        if not started.wait(timeout):
            raise RuntimeError("telemetry server failed to start")
        return self.port

    def stop_thread(self, timeout: float = 5.0) -> None:
        if self._thread_loop is not None:
            self._thread_loop.call_soon_threadsafe(self._thread_loop.stop)
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    # --------------------------------------------------------------- routes --
    def _route(self, target: str):
        """(status, content-type, body bytes) for one GET target."""
        parts = urlsplit(target)
        path = parts.path.rstrip("/") or "/"
        q = parse_qs(parts.query)
        if path == "/metricsz":
            if q.get("format", [""])[0] == "json":
                return 200, "application/json", render_json(self.snapshot())
            return (200, "text/plain; version=0.0.4; charset=utf-8",
                    render_prometheus(self.snapshot(), self.namespace))
        if path == "/healthz":
            if self.slo is None:
                return 200, "application/json", json.dumps(
                    {"state": "healthy", "slo": None})
            rep = self.slo.evaluate()
            code = 503 if rep["state"] == "unhealthy" else 200
            return code, "application/json", json.dumps(rep, default=str)
        if path == "/statusz":
            doc = {
                "uptime_s": round(time.time() - self._t_start, 3),
                "time": time.time(),
            }
            if self.status_fn is not None:
                try:
                    doc.update(self.status_fn())
                except Exception as e:   # status must never take down /statusz
                    doc["status_error"] = repr(e)
            if self.slo is not None:
                doc["slo"] = self.slo.evaluate()
            if self.flight is not None:
                doc["flight"] = self.flight.status()
            return 200, "application/json", json.dumps(doc, default=str)
        if path == "/tracez":
            try:
                n = max(1, int(q.get("n", ["64"])[0]))
            except ValueError:
                n = 64
            with self.tracer._lock:
                evs = list(self.tracer.events)[-n:]
            return 200, "application/json", json.dumps({
                "enabled": self.tracer.enabled,
                "ring_capacity": self.tracer.ring_capacity,
                "buffered": len(evs),
                "spans": evs,
            })
        return 404, "text/plain; charset=utf-8", f"no route {path!r}\n"

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            try:
                req = await asyncio.wait_for(reader.readline(), timeout=5.0)
            except asyncio.TimeoutError:
                return
            parts = req.decode("latin-1").split()
            if len(parts) < 2:
                return
            method, target = parts[0], parts[1]
            while True:                           # drain request headers
                line = await asyncio.wait_for(reader.readline(), timeout=5.0)
                if line in (b"\r\n", b"\n", b""):
                    break
            if method not in ("GET", "HEAD"):
                status, ctype, body = 405, "text/plain", "GET only\n"
            else:
                status, ctype, body = self._route(target)
            payload = body.encode() if isinstance(body, str) else body
            reason = {200: "OK", 404: "Not Found", 405: "Method Not Allowed",
                      503: "Service Unavailable"}.get(status, "OK")
            head = (f"HTTP/1.1 {status} {reason}\r\n"
                    f"Content-Type: {ctype}\r\n"
                    f"Content-Length: {len(payload)}\r\n"
                    f"Connection: close\r\n\r\n")
            writer.write(head.encode() + (b"" if method == "HEAD" else payload))
            await writer.drain()
        except (ConnectionError, asyncio.CancelledError):
            pass
        finally:
            try:
                writer.close()
            except Exception:
                pass


class PeriodicSampler:
    """Appends timestamped registry-snapshot deltas to a JSONL series.

    Each line is ``{"t": epoch, "dt_s": window, "series": diff}`` where
    ``diff`` is :func:`diff_snapshots` of consecutive snapshots —
    counters become per-window work (qps = value/dt_s), gauges keep
    their latest value, histograms carry the window's count/sum and
    re-estimated quantiles.  ``extra_fn`` merges host context (SLO
    state, data_version, staleness) into every line.  Runs on a daemon
    thread so both async services and synchronous drivers can host it;
    ``stop()`` writes one final sample so short runs still record.
    """

    def __init__(
        self,
        path: str,
        interval_s: float = 1.0,
        registries: Optional[List[MetricsRegistry]] = None,
        extra_fn: Optional[Callable[[], dict]] = None,
    ):
        self.path = path
        self.interval_s = interval_s
        self.registries = registries
        self.extra_fn = extra_fn
        self.samples = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._prev: Optional[Dict[str, dict]] = None
        self._prev_t = 0.0
        self._fh = None

    def _snapshot(self) -> Dict[str, dict]:
        regs = self.registries if self.registries is not None else [get_registry()]
        snap: Dict[str, dict] = {}
        for r in regs:
            snap = merge_snapshots(snap, r.snapshot()) if snap else r.snapshot()
        return snap

    def sample(self) -> dict:
        """Take (and append) one sample now; returns the written line."""
        now = time.time()
        cur = self._snapshot()
        prev = self._prev if self._prev is not None else {}
        line = {
            "t": round(now, 3),
            "dt_s": round(now - self._prev_t, 3) if self._prev is not None else 0.0,
            "series": diff_snapshots(prev, cur),
        }
        if self.extra_fn is not None:
            try:
                line.update(self.extra_fn())
            except Exception as e:
                line["extra_error"] = repr(e)
        self._prev, self._prev_t = cur, now
        self._fh.write(json.dumps(line, default=str) + "\n")
        self._fh.flush()
        self.samples += 1
        return line

    def start(self) -> "PeriodicSampler":
        self._fh = open(self.path, "a")
        self._prev, self._prev_t = self._snapshot(), time.time()

        def run():
            while not self._stop.wait(self.interval_s):
                self.sample()

        self._thread = threading.Thread(
            target=run, name="telemetry-sampler", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(max(5.0, 2 * self.interval_s))
        self._thread = None
        self.sample()                    # final window, so short runs record
        self._fh.close()
        self._fh = None
