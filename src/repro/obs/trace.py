"""Nestable spans with thread-local stacks and Chrome-trace export.

Default-off, near-zero-overhead: ``span(...)`` returns a shared no-op
context manager unless tracing was enabled, so instrumented hot paths
(message emission, level steps, the serving batcher) pay one truthiness
check when disabled.  Enabled, each span records wall time
(``perf_counter``) and host CPU time (``process_time``), its thread and
nesting depth, and arbitrary JSON-able attributes.

Two export formats:

- ``dump_jsonl(path)`` — one event per line, the raw sink CI uploads;
- ``dump_chrome_trace(path)`` — Chrome's Trace Event JSON ("X" complete
  events), loadable in ``chrome://tracing`` / https://ui.perfetto.dev.

jax interplay: spans optionally pass through
``jax.profiler.TraceAnnotation`` (so a concurrent ``jax.profiler``
capture shows the same names on the device timeline), and
:func:`fence` gives call sites explicit ``block_until_ready`` fencing —
async-dispatched device work would otherwise be misattributed to
whichever span happens to force the value later.  Fencing only happens
while tracing is enabled, so the disabled path never serializes
dispatch.  Span bodies that run under a jit trace are recorded as such
(``traced=True``) — their duration is compile/trace time, not runtime.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = [
    "Tracer", "span", "fence", "enable_tracing", "disable_tracing",
    "tracing_enabled", "get_tracer",
]


def _under_jit_trace() -> bool:
    """True when called from inside a jax trace (jit/vmap staging)."""
    try:
        import jax.core
        return not jax.core.trace_state_clean()
    except Exception:
        return False


class Tracer:
    """Process-wide span recorder.  One instance lives in this module;
    ``enable_tracing()`` switches it on and returns it."""

    def __init__(self, jax_annotations: bool = True):
        self.enabled = False
        self.jax_annotations = jax_annotations
        self.events: List[dict] = []
        self._lock = threading.Lock()
        self._tls = threading.local()
        # every thread's span stack, so clear() can reset them all — a
        # span leaked across an enable/disable cycle must not skew the
        # recorded depth of every later span on that thread
        self._stacks: List[list] = []
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------ recording --
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
            with self._lock:
                self._stacks.append(st)
        return st

    def record(self, ev: dict) -> None:
        with self._lock:
            self.events.append(ev)

    def clear(self) -> None:
        with self._lock:
            self.events.clear()
            for st in self._stacks:
                del st[:]
        self._t0 = time.perf_counter()

    # --------------------------------------------------------- ring buffer --
    @property
    def ring_capacity(self) -> Optional[int]:
        """Flight-recorder capacity, or None when unbounded."""
        return self.events.maxlen if isinstance(self.events, deque) else None

    def set_ring(self, capacity: int) -> None:
        """Flight-recorder mode: keep only the newest ``capacity`` events
        (overwrite-oldest, O(1) per span) — always-on tracing with bounded
        memory instead of the enable-dump-disable workflow."""
        if capacity <= 0:
            raise ValueError(f"ring capacity must be positive, got {capacity}")
        with self._lock:
            self.events = deque(self.events, maxlen=capacity)

    def set_unbounded(self) -> None:
        """Back to the unbounded list sink (full-trace capture mode)."""
        with self._lock:
            self.events = list(self.events)

    # ------------------------------------------------------------- rollups --
    def rollup(self) -> Dict[str, dict]:
        """Per-span-name {count, total_ms, max_ms} aggregate — the cheap
        summary BENCH reports embed."""
        with self._lock:
            events = list(self.events)
        out: Dict[str, dict] = {}
        for e in events:
            r = out.setdefault(e["name"], {"count": 0, "total_ms": 0.0,
                                           "max_ms": 0.0})
            r["count"] += 1
            r["total_ms"] += e["dur_ms"]
            r["max_ms"] = max(r["max_ms"], e["dur_ms"])
        for r in out.values():
            r["total_ms"] = round(r["total_ms"], 3)
            r["max_ms"] = round(r["max_ms"], 3)
        return out

    # ------------------------------------------------------------- exports --
    def dump_jsonl(self, path: str) -> int:
        with self._lock:
            events = list(self.events)
        with open(path, "w") as f:
            for e in events:
                f.write(json.dumps(e) + "\n")
        return len(events)

    def to_chrome_trace(self) -> dict:
        """Trace Event Format dict (open in Perfetto / chrome://tracing)."""
        with self._lock:
            events = list(self.events)
        trace = []
        for e in events:
            args = {k: v for k, v in e.items()
                    if k not in ("name", "ts_ms", "dur_ms", "tid")}
            trace.append({
                "name": e["name"], "ph": "X", "cat": "obs",
                "ts": round(e["ts_ms"] * 1e3, 3),     # µs
                "dur": round(e["dur_ms"] * 1e3, 3),
                "pid": 1, "tid": e["tid"],
                "args": args,
            })
        return {"traceEvents": trace, "displayTimeUnit": "ms"}

    def dump_chrome_trace(self, path: str) -> int:
        doc = self.to_chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(doc["traceEvents"])


class _Span:
    """Recording context manager (only built while tracing is enabled)."""

    __slots__ = ("tracer", "name", "attrs", "t0", "cpu0", "traced", "_jax_cm")

    def __init__(self, tracer: Tracer, name: str, attrs: dict):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self._jax_cm = None

    def __enter__(self):
        tr = self.tracer
        tr._stack().append(self)
        if tr.jax_annotations:
            try:
                import jax.profiler
                self._jax_cm = jax.profiler.TraceAnnotation(self.name)
                self._jax_cm.__enter__()
            except Exception:
                self._jax_cm = None
        self.traced = _under_jit_trace()
        self.cpu0 = time.process_time()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        cpu1 = time.process_time()
        tr = self.tracer
        stack = tr._stack()
        # exception-safe: pop our own frame even if inner spans leaked
        while stack and stack[-1] is not self:
            stack.pop()
        if stack:
            stack.pop()
        ev = {
            "name": self.name,
            "ts_ms": round((self.t0 - tr._t0) * 1e3, 6),
            "dur_ms": round((t1 - self.t0) * 1e3, 6),
            "cpu_ms": round((cpu1 - self.cpu0) * 1e3, 6),
            "tid": threading.get_ident() & 0xFFFF,
            "depth": len(stack),
        }
        if self.traced:
            ev["traced"] = True
        if exc_type is not None:
            ev["error"] = exc_type.__name__
        ev.update(self.attrs)
        tr.record(ev)
        if self._jax_cm is not None:
            try:
                self._jax_cm.__exit__(exc_type, exc, tb)
            except Exception:
                pass
        return False


class _NullSpan:
    """Shared do-nothing context manager — the disabled-mode fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


_NULL = _NullSpan()
_tracer = Tracer()


def get_tracer() -> Tracer:
    return _tracer


def tracing_enabled() -> bool:
    return _tracer.enabled


def enable_tracing(clear: bool = True, jax_annotations: bool = True) -> Tracer:
    if clear:
        _tracer.clear()
    _tracer.jax_annotations = jax_annotations
    _tracer.enabled = True
    return _tracer


def disable_tracing() -> Tracer:
    _tracer.enabled = False
    return _tracer


def span(name: str, **attrs):
    """``with span("boost.level", level=2):`` — records a span while
    tracing is enabled, otherwise returns the shared no-op manager."""
    if not _tracer.enabled:
        return _NULL
    return _Span(_tracer, name, attrs)


def fence(value: Any) -> Any:
    """``block_until_ready`` on ``value`` — but ONLY while tracing, so
    spans measure finished device work without the disabled path ever
    paying a synchronization."""
    if _tracer.enabled:
        try:
            import jax
            jax.block_until_ready(value)
        except Exception:
            pass
    return value
