"""Synthetic relational workloads (star / chain / snowflake schemas).

These generate the acyclic multi-table datasets the paper trains on:
τ tables, d features, join keys with controllable fanout, and a label
column on a designated fact table whose ground truth is a piecewise
(tree-like) or linear function of features spread across tables — so the
boosted regressor has real signal to recover.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.schema import Schema, Table
from repro.incremental.deltas import TableDelta


def _label(rng, feats, kind: str):
    """Piecewise/tree-ish or linear ground-truth label from a feature dict."""
    cols = list(feats.values())
    y = np.zeros_like(cols[0], dtype=np.float64)
    if kind == "linear":
        for i, c in enumerate(cols):
            y = y + ((-1) ** i) * 0.7 * c
    else:  # piecewise: axis-aligned steps — realizable by a shallow tree
        for i, c in enumerate(cols):
            thr = np.median(c)
            y = y + np.where(c >= thr, float(i + 1), -float(i + 1))
    y = y + 0.05 * rng.standard_normal(y.shape)
    return y.astype(np.float32)


def star_schema(
    seed: int = 0,
    n_fact: int = 512,
    n_dim: int = 64,
    n_dim_tables: int = 2,
    feats_per_dim: int = 2,
    fact_feats: int = 2,
    label_kind: str = "piecewise",
    dup_keys: bool = True,
) -> Schema:
    """Fact table joins `n_dim_tables` dimension tables on distinct keys.

    Fanout: many fact rows share a dimension key (dup_keys) — the regime
    where relational algorithms beat materialization (|J| = n_fact but
    features repeat).
    """
    rng = np.random.default_rng(seed)
    fact = {}
    dims = []
    key_cols = []
    for di in range(n_dim_tables):
        kc = f"k{di}"
        key_cols.append(kc)
        fact[kc] = (
            rng.integers(0, n_dim, n_fact) if dup_keys else rng.permutation(n_fact) % n_dim
        ).astype(np.int64)
        dcols = {kc: np.arange(n_dim, dtype=np.int64)}
        for fi in range(feats_per_dim):
            dcols[f"d{di}f{fi}"] = rng.standard_normal(n_dim).astype(np.float32)
        dims.append(Table(name=f"dim{di}", columns=dcols))
    for fi in range(fact_feats):
        fact[f"x{fi}"] = rng.standard_normal(n_fact).astype(np.float32)

    # label depends on features across tables (gathered through the keys)
    feats = {f"x{fi}": fact[f"x{fi}"] for fi in range(fact_feats)}
    for di, d in enumerate(dims):
        for fi in range(feats_per_dim):
            feats[f"d{di}f{fi}"] = d.columns[f"d{di}f{fi}"][fact[f"k{di}"]]
    fact["y"] = _label(rng, feats, label_kind)

    ft = Table(name="fact", columns=fact,
               feature_columns=tuple(f"x{fi}" for fi in range(fact_feats)))
    dim_tables = [
        Table(
            name=d.name,
            columns=d.columns,
            feature_columns=tuple(c for c in d.columns if not c.startswith("k")),
        )
        for d in dims
    ]
    return Schema([ft] + dim_tables, label=("fact", "y"))


def snowflake_schema(
    seed: int = 0,
    n_fact: int = 512,
    n_dim: int = 32,
    n_sub: int = 8,
    n_dim_tables: int = 2,
    feats_per_dim: int = 1,
    feats_per_sub: int = 1,
    fact_feats: int = 1,
    label_kind: str = "piecewise",
) -> Schema:
    """Star with normalized dimensions: fact ⋈ dim_i ⋈ sub_i.

    Each dimension table carries a foreign key into its own
    sub-dimension table (two join hops from the fact table) — the
    deepest acyclic shape the serving tests exercise.
    """
    rng = np.random.default_rng(seed)
    fact = {}
    dims, subs = [], []
    for di in range(n_dim_tables):
        kc, sc = f"k{di}", f"s{di}"
        fact[kc] = rng.integers(0, n_dim, n_fact).astype(np.int64)
        scols = {sc: np.arange(n_sub, dtype=np.int64)}
        for fi in range(feats_per_sub):
            scols[f"s{di}f{fi}"] = rng.standard_normal(n_sub).astype(np.float32)
        subs.append(Table(
            name=f"sub{di}", columns=scols,
            feature_columns=tuple(f"s{di}f{fi}" for fi in range(feats_per_sub)),
        ))
        dcols = {kc: np.arange(n_dim, dtype=np.int64),
                 sc: rng.integers(0, n_sub, n_dim).astype(np.int64)}
        for fi in range(feats_per_dim):
            dcols[f"d{di}f{fi}"] = rng.standard_normal(n_dim).astype(np.float32)
        dims.append(Table(name=f"dim{di}", columns=dcols))
    for fi in range(fact_feats):
        fact[f"x{fi}"] = rng.standard_normal(n_fact).astype(np.float32)

    # label depends on features across all three levels
    feats = {f"x{fi}": fact[f"x{fi}"] for fi in range(fact_feats)}
    for di in range(n_dim_tables):
        dk = fact[f"k{di}"]
        sk = dims[di].columns[f"s{di}"][dk]
        for fi in range(feats_per_dim):
            feats[f"d{di}f{fi}"] = dims[di].columns[f"d{di}f{fi}"][dk]
        for fi in range(feats_per_sub):
            feats[f"s{di}f{fi}"] = subs[di].columns[f"s{di}f{fi}"][sk]
    fact["y"] = _label(rng, feats, label_kind)

    ft = Table(name="fact", columns=fact,
               feature_columns=tuple(f"x{fi}" for fi in range(fact_feats)))
    dim_tables = [
        Table(name=d.name, columns=d.columns,
              feature_columns=tuple(c for c in d.columns if c.startswith("d")))
        for d in dims
    ]
    return Schema([ft] + dim_tables + subs, label=("fact", "y"))


def chain_schema(
    seed: int = 0,
    n_rows: int = 256,
    n_tables: int = 3,
    feats_per_table: int = 1,
    fanout: int = 2,
    label_kind: str = "piecewise",
) -> Schema:
    """T_1(k1,…) — T_2(k1,k2,…) — … — T_τ(k_{τ-1},…): a path join.

    Each adjacent pair shares one key; key multiplicity = `fanout` on the
    child side, so |J| grows ~ n_rows · fanout^{τ-1} while storage stays
    linear — the space regime motivating relational algorithms.
    """
    rng = np.random.default_rng(seed)
    tables = []
    n_keys = max(1, n_rows // fanout)
    first = {"k0": rng.integers(0, n_keys, n_rows).astype(np.int64)}
    for fi in range(feats_per_table):
        first[f"t0f{fi}"] = rng.standard_normal(n_rows).astype(np.float32)
    first["y"] = np.zeros(n_rows, np.float32)  # filled below
    tables.append(first)
    for ti in range(1, n_tables):
        n_t = n_keys * fanout
        cols = {f"k{ti-1}": (np.arange(n_t) % n_keys).astype(np.int64)}
        if ti < n_tables - 1:
            cols[f"k{ti}"] = rng.integers(0, n_keys, n_t).astype(np.int64)
        for fi in range(feats_per_table):
            cols[f"t{ti}f{fi}"] = rng.standard_normal(n_t).astype(np.float32)
        tables.append(cols)
        n_keys = max(1, n_t // fanout) if ti < n_tables - 1 else n_keys

    # label on table 0: depends on own features + mean of joined features
    feats = {f"t0f{fi}": tables[0][f"t0f{fi}"] for fi in range(feats_per_table)}
    tables[0]["y"] = _label(rng, feats, label_kind)

    out = []
    for ti, cols in enumerate(tables):
        fc = tuple(c for c in cols if c.startswith(f"t{ti}f"))
        out.append(Table(name=f"t{ti}", columns=cols, feature_columns=fc))
    return Schema(out, label=("t0", "y"))


# ---------------------------------------------------------------------------
# Delta streams (incremental-maintenance workloads)
# ---------------------------------------------------------------------------

def _key_columns(schema: Schema) -> set:
    """Join-key columns under natural-join semantics: any column name
    appearing in more than one table."""
    seen, keys = set(), set()
    for t in schema.tables:
        for c in t.columns:
            (keys if c in seen else seen).add(c)
    return keys


def delta_stream(
    schema: Schema,
    live_of: Callable[[str], np.ndarray],
    seed: int = 0,
    n_batches: int = 8,
    ops_per_batch: int = 6,
    tables: Optional[Sequence[str]] = None,
    p_insert: float = 0.35,
    p_delete: float = 0.3,
    new_key_prob: float = 0.15,
    min_live: int = 4,
) -> Iterator[List[TableDelta]]:
    """Random insert/delete/update batches against a live relational DB.

    ``live_of(table)`` must return the CURRENT live slot ids (deltas are
    generated lazily per batch, after the caller applied the previous
    one — e.g. ``ms.live_rows``).  Inserted key values are drawn from
    the observed key domain, except with ``new_key_prob`` a previously
    unseen key is minted (exercising the append-only key dictionaries);
    updates rewrite the non-key feature columns of live rows.  Deletes
    never shrink a table below ``min_live`` rows.
    """
    rng = np.random.default_rng(seed)
    key_cols = _key_columns(schema)
    names = [t.name for t in (schema.tables if tables is None
                              else [schema.table(n) for n in tables])]
    # observed key domains (grown as new keys are minted)
    domains: Dict[str, np.ndarray] = {}
    for t in schema.tables:
        for c in t.columns:
            if c in key_cols:
                vals = np.unique(np.asarray(t.col(c)))
                domains[c] = (np.union1d(domains[c], vals)
                              if c in domains else vals)

    def _insert_row(t: Table) -> Dict[str, np.ndarray]:
        row = {}
        for c, v in t.columns.items():
            v = np.asarray(v)
            if c in key_cols:
                if rng.random() < new_key_prob:
                    nk = domains[c].max() + int(rng.integers(1, 4))
                    domains[c] = np.append(domains[c], nk)
                    row[c] = np.asarray([nk], v.dtype)
                else:
                    row[c] = np.asarray([rng.choice(domains[c])], v.dtype)
            else:
                row[c] = rng.standard_normal(1).astype(v.dtype)
        return row

    for _ in range(n_batches):
        per_table: Dict[str, Dict] = {
            n: {"ins": [], "del": set(), "upd": set()} for n in names
        }
        for _ in range(ops_per_batch):
            name = names[int(rng.integers(len(names)))]
            t = schema.table(name)
            acc = per_table[name]
            r = rng.random()
            live = np.setdiff1d(live_of(name), np.fromiter(
                acc["del"] | acc["upd"], np.int64, len(acc["del"]) + len(acc["upd"])
            ))
            if r < p_insert or len(live) <= min_live:
                acc["ins"].append(_insert_row(t))
            elif r < p_insert + p_delete:
                acc["del"].add(int(rng.choice(live)))
            else:
                acc["upd"].add(int(rng.choice(live)))
        batch: List[TableDelta] = []
        for name, acc in per_table.items():
            t = schema.table(name)
            inserts = deletes = updates = None
            if acc["ins"]:
                inserts = {c: np.concatenate([r[c] for r in acc["ins"]])
                           for c in t.columns}
            if acc["del"]:
                deletes = np.asarray(sorted(acc["del"]), np.int64)
            if acc["upd"]:
                slots = np.asarray(sorted(acc["upd"]), np.int64)
                upd_cols = [c for c in t.feature_columns if c not in key_cols]
                if upd_cols:
                    updates = (slots, {
                        c: rng.standard_normal(len(slots)).astype(
                            np.asarray(t.col(c)).dtype)
                        for c in upd_cols
                    })
            if inserts or deletes is not None or updates is not None:
                batch.append(TableDelta(table=name, inserts=inserts,
                                        deletes=deletes, updates=updates))
        if batch:
            yield batch


def drift_stream(
    schema: Schema,
    live_of: Callable[[str], np.ndarray],
    seed: int = 0,
    n_batches: int = 6,
    rows_per_batch: int = 8,
    feature_tables: Optional[Sequence[str]] = None,
    label_shift: float = 0.75,
    label_scale: float = 0.5,
) -> Iterator[List[TableDelta]]:
    """Concept-drift workload for incremental RETRAINING benchmarks.

    Unlike :func:`delta_stream` (which churns rows but leaves the
    label-generating process alone — a serving workload), each batch
    here rewrites the feature values of live rows on one rotating
    feature table AND shifts the labels of a random block of live
    label-table rows.  Label perturbations are expressed in units of the
    CURRENT live labels' std (y ← μ + shift·σ + scale·σ·ε), so the
    drift severity is comparable across workloads whose label variances
    differ by orders of magnitude.  The maintained aggregates absorb the
    delta cheaply, but the *model* goes stale — the regime where
    ``IncrementalBooster.refit`` must append trees, not just refresh
    messages."""
    rng = np.random.default_rng(seed)
    key_cols = _key_columns(schema)
    names = list(feature_tables) if feature_tables is not None else [
        t.name for t in schema.tables
    ]
    lbl_t, lbl_c = schema.label_table, schema.label_column
    # drift severity in units of the ORIGINAL label distribution (the
    # dynamic store's current values aren't visible through `live_of`,
    # and a fixed reference keeps repeated shifts from compounding)
    y0 = np.asarray(schema.table(lbl_t).col(lbl_c)).astype(np.float64)
    mu, sd = float(y0.mean()), float(y0.std() + 1e-9)
    for b in range(n_batches):
        batch: List[TableDelta] = []
        name = names[b % len(names)]
        t = schema.table(name)
        live = live_of(name)
        k = min(rows_per_batch, len(live))
        if k:
            slots = np.sort(rng.choice(live, size=k, replace=False))
            cols = {
                c: rng.standard_normal(k).astype(np.asarray(t.col(c)).dtype)
                for c in t.feature_columns if c not in key_cols
            }
            if cols:
                batch.append(TableDelta(table=name, updates=(slots, cols)))
        livef = live_of(lbl_t)
        kf = min(rows_per_batch, len(livef))
        if kf:
            fslots = np.sort(rng.choice(livef, size=kf, replace=False))
            newy = (mu + label_shift * sd
                    + label_scale * sd * rng.standard_normal(kf)
                    ).astype(np.float32)
            batch.append(TableDelta(table=lbl_t,
                                    updates=(fslots, {lbl_c: newy})))
        if batch:
            yield batch
