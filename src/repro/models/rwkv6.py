"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free, data-dependent decay.

Recurrence per head (k-dim dk = v-dim dv = head_size):
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
    o_t = r_t (S_{t-1} + diag(u) k_tᵀ v_t)

Training uses a *chunked* parallel form (TPU adaptation — the GPU
reference is a CUDA scan): within a chunk of length c, the pairwise
decay exponents cum_{i-1} − cum_j (j < i) are all ≤ 0, so every
exponential lies in (0, 1] — unconditionally stable without the
normalization tricks GPU kernels need.  Cross-chunk state is carried by
``lax.scan``.  Decode is the O(1) recurrent step.

The same math is the oracle for kernels/rwkv6_chunk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _dense_init, rmsnorm


def init_rwkv_block(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    hs = cfg.rwkv_head_size
    H = D // hs
    ks = jax.random.split(key, 12)
    lora = 64
    return {
        # token-shift data-dependent lerp (5 targets: w, k, v, r, g)
        "mu": (jax.random.uniform(ks[0], (5, D)) * 0.5 + 0.25).astype(dtype),
        # decay: w_t = exp(-exp(w0 + tanh(x @ A) @ B))
        "w0": (jnp.zeros((D,)) - 4.0).astype(jnp.float32),
        "wA": _dense_init(ks[1], (D, lora), dtype),
        "wB": (jax.random.normal(ks[2], (lora, D)) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[3], (H, hs)) * 0.1).astype(jnp.float32),
        "wr": _dense_init(ks[4], (D, D), dtype),
        "wk": _dense_init(ks[5], (D, D), dtype),
        "wv": _dense_init(ks[6], (D, D), dtype),
        "wg": _dense_init(ks[7], (D, D), dtype),
        "wo": _dense_init(ks[8], (D, D), dtype, scale=1.0 / np.sqrt(2 * cfg.n_layers)),
        "ln_x": jnp.ones((D,), dtype),
        # channel mix
        "mu_c": (jax.random.uniform(ks[9], (2, D)) * 0.5 + 0.25).astype(dtype),
        "ck": _dense_init(ks[10], (D, cfg.d_ff), dtype),
        "cv": _dense_init(ks[11], (cfg.d_ff, D), dtype, scale=1.0 / np.sqrt(2 * cfg.n_layers)),
        "cr": _dense_init(jax.random.fold_in(key, 99), (D, D), dtype),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / supplied state at t=0)."""
    pad = jnp.zeros_like(x[:, :1]) if last is None else last[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _projections(p, cfg, x, x_prev):
    """Shared by train/decode: r,k,v,g,logw from (B,S,D) inputs."""
    dx = x_prev - x
    mu = p["mu"].astype(x.dtype)                    # (5, D)
    xw, xk, xv, xr, xg = [x + dx * mu[i] for i in range(5)]
    logw = -jnp.exp(
        p["w0"] + (jnp.tanh(xw @ p["wA"]) @ p["wB"]).astype(jnp.float32)
    )                                               # (B,S,D) ≤ 0
    r = xr @ p["wr"]
    k = xk @ p["wk"]
    v = xv @ p["wv"]
    g = jax.nn.silu(xg @ p["wg"])
    return r, k, v, g, logw


def _heads(cfg, t):
    B, S, D = t.shape
    hs = cfg.rwkv_head_size
    return t.reshape(B, S, D // hs, hs)


def rwkv_chunked(r, k, v, logw, u, chunk):
    """Chunked WKV: r,k,v (B,S,H,hs) f32; logw (B,S,H,hs) ≤ 0; u (H,hs).
    Returns (B,S,H,hs) and leaves no state (training form, S % chunk == 0
    after padding by caller)."""
    B, S, H, hs = r.shape
    nc = S // chunk
    rc = r.reshape(B, nc, chunk, H, hs).transpose(1, 0, 3, 2, 4)  # (nc,B,H,c,hs)
    kc = k.reshape(B, nc, chunk, H, hs).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, nc, chunk, H, hs).transpose(1, 0, 3, 2, 4)
    wc = logw.reshape(B, nc, chunk, H, hs).transpose(1, 0, 3, 2, 4)

    def body(S0, inp):
        rr, kk, vv, ww = inp                         # (B,H,c,hs)
        cum = jnp.cumsum(ww, axis=2)                 # inclusive, ≤ 0, decreasing
        cum_excl = cum - ww                          # exclusive
        # intra-chunk: A_ij = Σ_d r_id k_jd e^{cum_excl_i − cum_j}  (j < i)
        E = jnp.exp(
            jnp.clip(cum_excl[:, :, :, None, :] - cum[:, :, None, :, :], -60.0, 0.0)
        )                                            # (B,H,c,c,hs)
        A = jnp.einsum("bhid,bhjd,bhijd->bhij", rr, kk, E)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), -1)
        A = jnp.where(mask, A, 0.0)
        # u-bonus diagonal (current token)
        diag = jnp.einsum("bhid,hd,bhid->bhi", rr, u, kk)
        out = jnp.einsum("bhij,bhjd->bhid", A, vv) + diag[..., None] * vv
        # inter-chunk: r_i ⊙ e^{cum_excl_i} applied to carried state
        rW = rr * jnp.exp(cum_excl)
        out = out + jnp.einsum("bhik,bhkd->bhid", rW, S0)
        # state update: S' = diag(e^{cum_C}) S + Σ_j (k_j e^{cum_C − cum_j})ᵀ v_j
        kW = kk * jnp.exp(cum[:, :, -1:, :] - cum)
        S1 = jnp.exp(cum[:, :, -1, :])[..., None] * S0 + jnp.einsum(
            "bhjk,bhjd->bhkd", kW, vv
        )
        return S1, out

    S0 = jnp.zeros((B, H, hs, hs), jnp.float32)
    _, out = jax.lax.scan(body, S0, (rc, kc, vc, wc))
    return out.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hs)


def time_mix(p, cfg: ModelConfig, x, use_kernel: bool = False):
    """Training/prefill path.  x: (B,S,D)."""
    B, S, D = x.shape
    hs = cfg.rwkv_head_size
    r, k, v, g, logw = _projections(p, cfg, x, _shift(x))
    rh = _heads(cfg, r).astype(jnp.float32)
    kh = _heads(cfg, k).astype(jnp.float32)
    vh = _heads(cfg, v).astype(jnp.float32)
    wh = _heads(cfg, logw)
    chunk = cfg.ssm_chunk
    pad = (-S) % chunk
    if pad:
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        rh, kh, vh, wh = zf(rh), zf(kh), zf(vh), zf(wh)
    if use_kernel:
        from repro.kernels.rwkv6_chunk import ops as _kops

        out = _kops.rwkv6_chunk(rh, kh, vh, wh, p["u"], chunk)
    else:
        out = rwkv_chunked(rh, kh, vh, wh, p["u"], chunk)
    out = out[:, :S].reshape(B, S, D)
    out = rmsnorm(out, p["ln_x"].astype(jnp.float32), 1e-5)
    return (out.astype(x.dtype) * g) @ p["wo"]


def time_mix_step(p, cfg: ModelConfig, x, state):
    """Decode: x (B,1,D); state dict {S:(B,H,hs,hs), x_last:(B,D)}."""
    B = x.shape[0]
    r, k, v, g, logw = _projections(p, cfg, x, state["x_last"][:, None])
    rh = _heads(cfg, r)[:, 0].astype(jnp.float32)     # (B,H,hs)
    kh = _heads(cfg, k)[:, 0].astype(jnp.float32)
    vh = _heads(cfg, v)[:, 0].astype(jnp.float32)
    wh = jnp.exp(_heads(cfg, logw)[:, 0])             # (B,H,hs)
    S0 = state["S"]
    kv = jnp.einsum("bhk,bhd->bhkd", kh, vh)
    out = jnp.einsum("bhk,bhkd->bhd", rh, S0 + p["u"][None, :, :, None] * kv)
    S1 = wh[..., None] * S0 + kv
    D = cfg.d_model
    out = out.reshape(B, 1, D)
    out = rmsnorm(out, p["ln_x"].astype(jnp.float32), 1e-5)
    out = (out.astype(x.dtype) * g) @ p["wo"]
    return out, {"S": S1, "x_last": x[:, 0]}


def channel_mix(p, cfg: ModelConfig, x, x_last=None):
    xp = _shift(x, x_last)
    dx = xp - x
    mu = p["mu_c"].astype(x.dtype)
    xk = x + dx * mu[0]
    xr = x + dx * mu[1]
    k = jnp.square(jax.nn.relu(xk @ p["ck"]))
    return jax.nn.sigmoid(xr @ p["cr"]) * (k @ p["cv"])
