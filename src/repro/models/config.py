"""Model configuration — one dataclass covers all 10 assigned architectures.

``kind`` selects the block wiring:
  dense  — decoder-only transformer (GQA)            [qwen2.5, tinyllama,
                                                      llama3, granite, llava]
  moe    — dense + mixture-of-experts FFN            [dbrx, llama4-scout]
  rwkv   — RWKV-6 'Finch' (attention-free)           [rwkv6]
  hybrid — parallel attention + SSM heads (Hymba)    [hymba]
  encdec — encoder–decoder with cross-attention      [seamless-m4t]

``frontend`` marks modality stubs: the backbone consumes precomputed
patch/frame embeddings supplied by input_specs() (assignment rule).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str                       # dense | moe | rwkv | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_kv_heads: int = 0             # 0 → = n_heads
    d_head: int = 0                 # 0 → d_model // n_heads
    # attention details
    qkv_bias: bool = False          # qwen2-style QKV bias
    rope_theta: float = 1e4
    window: Optional[int] = None    # sliding-window size (None = full)
    global_layers: Tuple[int, ...] = ()  # full-attn layers when window set
    # MoE
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM / RWKV
    ssm_state: int = 0
    ssm_heads: int = 0
    rwkv_head_size: int = 64
    # encoder–decoder
    enc_layers: int = 0
    # modality stub
    frontend: Optional[str] = None  # "patches" | "frames"
    meta_tokens: int = 0            # Hymba learnable prefix tokens
    # numerics / structure
    act: str = "swiglu"             # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    # attention impl knobs (perf)
    q_chunk: int = 512              # online-softmax query block
    kv_chunk: int = 1024
    ssm_chunk: int = 64
    use_pallas: bool = False        # TPU target kernels (tests use interpret)
    # parallelism hints (see distributed/sharding.py)
    seq_shard: bool = False         # sequence-parallel activations (beyond-paper perf)

    @property
    def padded_vocab(self) -> int:
        """Embedding/logits vocab padded to 512 (= 16 tp × 32 lanes) — the
        standard trick so vocab-sharded logits divide any mesh axis.
        Loss/decode mask ids ≥ vocab."""
        return ((self.vocab + 511) // 512) * 512

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.kind == "encdec"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (assignment rule: SSM/hybrid/linear only)."""
        return self.kind in ("rwkv", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
