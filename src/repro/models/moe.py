"""Mixture-of-experts FFN with capacity-based scatter dispatch.

Dispatch avoids the (tokens × experts × capacity) one-hot blowup: tokens
are ranked within their expert by a cumulative-count (position = rank in
arrival order), dropped beyond capacity, scattered into a (E, C, D)
buffer, run through a grouped GEMM, and combined back with router
weights.  Expert-parallel sharding puts E on the `model` mesh axis; GSPMD
inserts the dispatch/combine all-to-alls (DESIGN.md §4).

Covers dbrx-132b (16e top-4) and llama4-scout (16e top-1 + shared expert).
Aux load-balance loss is the Switch/GShard form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _dense_init


def init_moe(key, cfg: ModelConfig, dtype):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": _dense_init(k1, (D, E), jnp.float32),
        "w_gate": _dense_init(k2, (E, D, F), dtype),
        "w_up": _dense_init(k3, (E, D, F), dtype),
        "w_down": _dense_init(k4, (E, F, D), dtype, scale=1.0 / np.sqrt(2 * cfg.n_layers)),
    }
    if cfg.shared_expert:
        ks = jax.random.split(k5, 3)
        p["shared"] = {
            "w_gate": _dense_init(ks[0], (D, F), dtype),
            "w_up": _dense_init(ks[1], (D, F), dtype),
            "w_down": _dense_init(ks[2], (F, D), dtype, scale=1.0 / np.sqrt(2 * cfg.n_layers)),
        }
    return p


def moe_ffn(p, cfg: ModelConfig, x, capacity_factor=None):
    """x: (B, S, D) → (out, aux_loss).

    capacity_factor override: serving paths pass a large factor (≈dropless;
    train-time token dropping must not perturb decode results)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32)) @ p["router"]               # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                           # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E · Σ_e f_e · p̄_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=1), axis=0
    )
    aux = E * jnp.sum(me * ce) * cfg.router_aux_coef

    C = int(np.ceil(T * K / E * (capacity_factor or cfg.capacity_factor)))
    C = min(max(C, 1), T * K)

    flat_e = idx.reshape(-1)                                       # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gate.reshape(-1)

    # rank within expert (arrival order): positions via cumsum of one-hot
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)            # (T*K, E)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)                    # exclusive
    rank = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = rank < C

    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[
        jnp.where(keep, flat_e, 0), jnp.where(keep, rank, 0)
    ].add(jnp.where(keep[:, None], xt[flat_t], 0).astype(x.dtype))

    # grouped GEMM over experts
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * jnp.einsum(
            "ecd,edf->ecf", buf, p["w_up"]
        )
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w_up"]))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])           # (E, C, D)

    gathered = out_buf[
        jnp.where(keep, flat_e, 0), jnp.where(keep, rank, 0)
    ]                                                               # (T*K, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    contrib = gathered * flat_g[:, None].astype(x.dtype)
    out = jax.ops.segment_sum(contrib, flat_t, num_segments=T)

    if cfg.shared_expert:
        s = p["shared"]
        h = jax.nn.silu(xt @ s["w_gate"]) * (xt @ s["w_up"])
        out = out + h @ s["w_down"]
    return out.reshape(B, S, D).astype(x.dtype), aux
