"""Mamba-2-style selective SSM branch (for Hymba's parallel heads).

Per head (state size N, head dim P):
    h_t = a_t · h_{t-1} + (dt_t x_t) B_tᵀ        h ∈ R^{N×P}
    y_t = C_t h_t + D ⊙ x_t
with scalar per-head decay a_t = exp(-dt_t · exp(A_log)) (dt via
softplus).  Same chunked-scan structure as rwkv6.py, with scalar decay
so the pairwise decay matrix is (c × c) per head — the SSD "attention
form" (arXiv:2405.21060), all exponents ≤ 0 (stable).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _dense_init


def init_ssm(key, cfg: ModelConfig, dtype, d_inner: int):
    N = cfg.ssm_state
    H = cfg.ssm_heads or cfg.n_heads
    P = d_inner // H
    ks = jax.random.split(key, 6)
    return {
        "wx": _dense_init(ks[0], (cfg.d_model, d_inner), dtype),
        "wB": _dense_init(ks[1], (cfg.d_model, H * N), dtype),
        "wC": _dense_init(ks[2], (cfg.d_model, H * N), dtype),
        "wdt": _dense_init(ks[3], (cfg.d_model, H), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "Dskip": jnp.ones((H, P), jnp.float32),
        "wo": _dense_init(ks[4], (d_inner, cfg.d_model), dtype,
                          scale=1.0 / np.sqrt(2 * cfg.n_layers)),
        "conv": (jax.random.normal(ks[5], (4, d_inner)) * 0.1).astype(dtype),
    }


def _conv1d(x, w):
    """Depthwise causal conv, kernel 4.  x: (B,S,D), w: (4,D)."""
    pads = [jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, : x.shape[1]] for k in range(w.shape[0])]
    return sum(pads[k] * w[w.shape[0] - 1 - k] for k in range(w.shape[0]))


def _inputs(p, cfg, u):
    B, S, _ = u.shape
    H = cfg.ssm_heads or cfg.n_heads
    N = cfg.ssm_state
    x = jax.nn.silu(_conv1d(u @ p["wx"], p["conv"]))
    P = x.shape[-1] // H
    x = x.reshape(B, S, H, P).astype(jnp.float32)
    Bm = (u @ p["wB"]).reshape(B, S, H, N).astype(jnp.float32)
    Cm = (u @ p["wC"]).reshape(B, S, H, N).astype(jnp.float32)
    dt = jax.nn.softplus((u @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    loga = -dt * jnp.exp(p["A_log"])                 # (B,S,H) ≤ 0
    return x, Bm, Cm, dt, loga


def ssm_chunked(x, Bm, Cm, dt, loga, Dskip, chunk):
    """x:(B,S,H,P), Bm/Cm:(B,S,H,N), dt/loga:(B,S,H) → y:(B,S,H,P)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    nc = S // chunk
    r = lambda t: t.reshape(B, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    xc, Bc, Cc, dc, lc = r(x), r(Bm), r(Cm), r(dt), r(loga)

    def body(h0, inp):
        xx, BB, CC, dd, ll = inp                      # (B,c,H,*)
        cum = jnp.cumsum(ll, axis=1)                  # (B,c,H) ≤ 0
        cum_excl = cum - ll
        # SSD attention form: L_ij = e^{cum_i − cum_j} for j ≤ i (incl. diag)
        L = jnp.exp(
            jnp.clip(cum[:, :, None, :] - cum[:, None, :, :], -60.0, 0.0)
        )                                             # (B,c,c,H)
        A = jnp.einsum("bihn,bjhn->bijh", CC, BB) * L
        mask = jnp.tril(jnp.ones((xx.shape[1], xx.shape[1]), bool))
        A = jnp.where(mask[None, :, :, None], A, 0.0)
        y = jnp.einsum("bijh,bjh,bjhp->bihp", A, dd, xx)
        # inter-chunk
        y = y + jnp.einsum("bihn,bih,bhnp->bihp", CC, jnp.exp(cum), h0)
        # state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)  # (B,c,H)
        h1 = jnp.exp(cum[:, -1, :])[:, :, None, None] * h0 + jnp.einsum(
            "bjhn,bjh,bjh,bjhp->bhnp", BB, dd, decay_to_end, xx
        )
        return h1, y

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, y = jax.lax.scan(body, h0, (xc, Bc, Cc, dc, lc))
    y = y.swapaxes(0, 1).reshape(B, S, H, P)
    return y + x * Dskip


def ssm_branch(p, cfg: ModelConfig, u, chunk=None):
    """Training/prefill.  u: (B,S,D) → (B,S,D)."""
    B, S, D = u.shape
    chunk = chunk or cfg.ssm_chunk
    x, Bm, Cm, dt, loga = _inputs(p, cfg, u)
    pad = (-S) % chunk
    if pad:
        f4 = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        x, Bm, Cm, dt, loga = f4(x), f4(Bm), f4(Cm), f4(dt), f4(loga)
    y = ssm_chunked(x, Bm, Cm, dt, loga, p["Dskip"], chunk)[:, :S]
    B_, S_, H, P = y.shape
    return y.reshape(B, S, H * P).astype(u.dtype) @ p["wo"]


def ssm_step(p, cfg: ModelConfig, u, state):
    """Decode.  u: (B,1,D); state {h:(B,H,N,P), conv:(B,4,d_inner)}."""
    B = u.shape[0]
    H = cfg.ssm_heads or cfg.n_heads
    N = cfg.ssm_state
    xin = (u @ p["wx"])[:, 0]                         # (B, d_inner)
    conv_buf = jnp.concatenate([state["conv"][:, 1:], xin[:, None]], axis=1)
    w = p["conv"]
    # _conv1d: out_t = Σ_j w[j] · x_{t-(K-1)+j}; conv_buf[j] = x_{t-(K-1)+j}
    x = jax.nn.silu(jnp.einsum("bkd,kd->bd", conv_buf, w))
    P = x.shape[-1] // H
    x = x.reshape(B, H, P).astype(jnp.float32)
    Bm = (u @ p["wB"])[:, 0].reshape(B, H, N).astype(jnp.float32)
    Cm = (u @ p["wC"])[:, 0].reshape(B, H, N).astype(jnp.float32)
    dt = jax.nn.softplus((u @ p["wdt"])[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(-dt * jnp.exp(p["A_log"]))            # (B,H)
    h1 = a[..., None, None] * state["h"] + jnp.einsum(
        "bhn,bh,bhp->bhnp", Bm, dt, x
    )
    y = jnp.einsum("bhn,bhnp->bhp", Cm, h1) + x * p["Dskip"]
    out = y.reshape(B, 1, H * P).astype(u.dtype) @ p["wo"]
    return out, {"h": h1, "conv": conv_buf}
