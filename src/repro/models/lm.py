"""Unified model facade: init / train loss / prefill / decode for the five
block kinds (dense, moe, rwkv, hybrid, encdec).

Training and prefill scan over stacked layer parameters (compile-time and
HLO size stay O(1) in depth — production practice, MaxText-style) with
jax.checkpoint around each block (remat).  Decode unrolls the layer loop
(single-token step; per-layer cache shapes may differ, e.g. Hymba's
sliding-window layers keep a window-sized cache while its 3 global
layers keep the full context — the honest memory story at 500k).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from . import layers as L
from . import moe as MOE
from . import rwkv6 as RWKV
from . import ssm as SSM
from repro.distributed.sharding import constrain

GLOBAL_WINDOW = jnp.int32(1 << 30)   # "window" for full-attention layers


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------- blocks --

def init_block(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 8)
    if cfg.kind == "rwkv":
        return {
            "ln1": L.init_rmsnorm(cfg.d_model, dtype),
            "ln2": L.init_rmsnorm(cfg.d_model, dtype),
            "mix": RWKV.init_rwkv_block(ks[0], cfg, dtype),
        }
    p = {
        "ln1": L.init_rmsnorm(cfg.d_model, dtype),
        "ln2": L.init_rmsnorm(cfg.d_model, dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
    }
    if cfg.kind in ("dense", "encdec"):
        p["mlp"] = L.init_mlp(ks[1], cfg, dtype)
    elif cfg.kind == "moe":
        p["moe"] = MOE.init_moe(ks[1], cfg, dtype)
    elif cfg.kind == "hybrid":
        p["mlp"] = L.init_mlp(ks[1], cfg, dtype)
        p["ssm"] = SSM.init_ssm(ks[2], cfg, dtype, cfg.n_heads * cfg.head_dim)
        p["bn_a"] = L.init_rmsnorm(cfg.d_model, dtype)
        p["bn_s"] = L.init_rmsnorm(cfg.d_model, dtype)
    return p


def init_cross_block(key, cfg: ModelConfig, dtype):
    """Decoder block with cross-attention (encdec)."""
    p = init_block(key, cfg, dtype)
    ks = jax.random.split(jax.random.fold_in(key, 7), 2)
    p["ln_x"] = L.init_rmsnorm(cfg.d_model, dtype)
    p["xattn"] = L.init_attention(ks[0], cfg, dtype)
    return p


def block_train(p, cfg: ModelConfig, x, positions, window, *, causal=True,
                enc_out=None, enc_pos=None):
    """One block forward (train/prefill math).  window: traced int32
    (GLOBAL_WINDOW = full attention).  Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.kind == "rwkv":
        h = L.rmsnorm(x, p["ln1"]["scale"], cfg.norm_eps)
        x = x + RWKV.time_mix(p["mix"], cfg, h, use_kernel=cfg.use_pallas)
        h = L.rmsnorm(x, p["ln2"]["scale"], cfg.norm_eps)
        x = x + RWKV.channel_mix(p["mix"], cfg, h)
        return x, aux

    # Megatron-style SP↔TP switch: the residual stream is seq-sharded over
    # tp between blocks; inside, activations go full-seq so GSPMD shards
    # heads/d_ff over tp (otherwise it fully gathers the *weights* per
    # layer — the FSDP-compute regime — which is what blows temp memory).
    h = L.rmsnorm(x, p["ln1"]["scale"], cfg.norm_eps)
    h = constrain(h, "dp", None, None)
    attn_out = L.attention(
        p["attn"], cfg, h, positions, layer_window=window, causal=causal
    )
    if cfg.kind == "hybrid":
        ssm_out = SSM.ssm_branch(p["ssm"], cfg, h)
        attn_out = 0.5 * (
            L.rmsnorm(attn_out, p["bn_a"]["scale"], cfg.norm_eps)
            + L.rmsnorm(ssm_out, p["bn_s"]["scale"], cfg.norm_eps)
        )
    # branch outputs constrained full-seq so the bwd cotangents match the
    # recomputed full-seq activations (else GSPMD gathers weight-sized
    # buffers to reconcile the dW dots)
    x = x + constrain(attn_out, "dp", None, None)
    if enc_out is not None:
        h = L.rmsnorm(x, p["ln_x"]["scale"], cfg.norm_eps)
        x = x + constrain(
            L.attention(
                p["xattn"], cfg, h, positions, kv=enc_out, kv_positions=enc_pos
            ),
            "dp", None, None,
        )
    h = L.rmsnorm(x, p["ln2"]["scale"], cfg.norm_eps)
    h = constrain(h, "dp", None, None)
    if cfg.kind == "moe":
        mo, a = MOE.moe_ffn(p["moe"], cfg, h)
        x = x + constrain(mo, "dp", None, None)
        aux = aux + a
    else:
        x = x + constrain(L.mlp(p["mlp"], cfg, h), "dp", None, None)
    return x, aux


# ----------------------------------------------------------------- model --

@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    @property
    def _uniform_cache(self) -> bool:
        """All layers share one cache shape → prefill/decode scan layers.
        Hybrid (Hymba) has per-layer spans (window vs global) → unrolled."""
        return self.cfg.kind in ("dense", "moe", "encdec", "rwkv")

    # ------------------------------------------------------------- init --
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dt = _dtype(cfg)
        k_emb, k_layers, k_enc, k_meta, k_lnf = jax.random.split(key, 5)
        params: Dict[str, Any] = {"embed": L.init_embed(k_emb, cfg, dt)}
        n_dec = cfg.n_layers
        keys = jax.random.split(k_layers, n_dec)
        mk = init_cross_block if cfg.is_encdec else init_block
        params["layers"] = jax.vmap(lambda k: mk(k, cfg, dt))(keys)
        if cfg.is_encdec:
            ekeys = jax.random.split(k_enc, cfg.enc_layers)
            params["enc_layers"] = jax.vmap(lambda k: init_block(k, cfg, dt))(ekeys)
            params["enc_ln_f"] = L.init_rmsnorm(cfg.d_model, dt)
        params["ln_f"] = L.init_rmsnorm(cfg.d_model, dt)
        if cfg.meta_tokens:
            params["meta"] = (
                jax.random.normal(k_meta, (cfg.meta_tokens, cfg.d_model)) * 0.02
            ).astype(dt)
        return params

    def _layer_windows(self) -> np.ndarray:
        """Static per-layer window sizes (1<<30 = full attention)."""
        cfg = self.cfg
        w = np.full((cfg.n_layers,), cfg.window or (1 << 30), np.int32)
        for g in cfg.global_layers:
            w[g] = 1 << 30
        return w

    # ------------------------------------------------------ trunk (scan) --
    def _run_stack(self, stack_params, x, positions, *, causal=True,
                   enc_out=None, enc_pos=None, windows=None):
        cfg = self.cfg
        if windows is None:
            windows = jnp.asarray(self._layer_windows())
        # initial carry must match the in-scan carry sharding (scan unifies
        # them): batch over dp, seq over tp (sequence parallelism)
        x = constrain(x, "dp", "tp", None)

        def body(carry, inp):
            x, aux = carry
            p, w = inp
            fn = lambda p_, x_: block_train(
                p_, cfg, x_, positions, w, causal=causal,
                enc_out=enc_out, enc_pos=enc_pos,
            )
            if cfg.remat:
                fn = jax.checkpoint(fn)
            x, a = fn(p, x)
            # sequence-parallel residual stream: the *returned* carry is what
            # scan saves per layer for the backward pass — sharding seq over
            # tp divides saved-activation memory by 16 (essential at 405B:
            # 126 × mb·S·D bf16 would not fit per device otherwise)
            x = constrain(x, "dp", "tp", None)
            return (x, aux + a), None

        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), (stack_params, windows)
            )
        else:
            aux = jnp.zeros((), jnp.float32)
            n = jax.tree.leaves(stack_params)[0].shape[0]
            for i in range(n):
                p = jax.tree.map(lambda a: a[i], stack_params)
                (x, aux), _ = body((x, aux), (p, windows[i]))
        return x, aux

    # ------------------------------------------------------------ inputs --
    def _embed_inputs(self, params, batch):
        """Tokens (+ modality stubs / meta tokens) → (h, positions, n_prefix)."""
        cfg = self.cfg
        h = L.embed(params["embed"], batch["tokens"])
        n_prefix = 0
        if cfg.frontend == "patches" and "patches" in batch:
            h = jnp.concatenate([batch["patches"].astype(h.dtype), h], axis=1)
            n_prefix = batch["patches"].shape[1]
        if cfg.meta_tokens:
            B = h.shape[0]
            meta = jnp.broadcast_to(
                params["meta"][None], (B, cfg.meta_tokens, cfg.d_model)
            )
            h = jnp.concatenate([meta, h], axis=1)
            n_prefix += cfg.meta_tokens
        B, S = h.shape[:2]
        # (dp, None, None): a (dp, None, tp) target trips an XLA SPMD
        # gather-reshard verifier bug (dynamic-slice size mismatch); the
        # full-D per-device gather output is only ~134 MB here
        h = constrain(h, "dp", None, None)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        return h, positions, n_prefix

    # -------------------------------------------------------------- loss --
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """Next-token CE (+ MoE aux).  batch: tokens (B,S) [+ patches /
        src_frames / loss_mask]."""
        cfg = self.cfg
        if cfg.is_encdec:
            enc_h = batch["src_frames"].astype(_dtype(cfg))
            B, Se = enc_h.shape[:2]
            enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None], (B, Se))
            enc_h, aux_e = self._run_stack(
                params["enc_layers"], enc_h, enc_pos, causal=False,
                windows=jnp.full((cfg.enc_layers,), GLOBAL_WINDOW, jnp.int32),
            )
            enc_h = L.rmsnorm(enc_h, params["enc_ln_f"]["scale"], cfg.norm_eps)
            h, positions, _ = self._embed_inputs(params, batch)
            h, aux = self._run_stack(
                params["layers"], h, positions, enc_out=enc_h, enc_pos=enc_pos
            )
            aux = aux + aux_e
            n_prefix = 0
        else:
            h, positions, n_prefix = self._embed_inputs(params, batch)
            h, aux = self._run_stack(params["layers"], h, positions)
        h = L.rmsnorm(h, params["ln_f"]["scale"], cfg.norm_eps)
        h = h[:, n_prefix:]
        # logits sharded (batch=dp, seq, vocab=tp): the (B,S,V) fp32 tensor
        # is the single largest activation — never replicate it
        logits = L.unembed(params["embed"], cfg, h[:, :-1]).astype(jnp.float32)
        logits = constrain(logits, "dp", None, "tp")
        logits = L.mask_pad_logits(cfg, logits)
        targets = batch["tokens"][:, 1:]
        mask = batch.get("loss_mask", jnp.ones_like(targets, jnp.float32))
        mask = mask[:, : targets.shape[1]].astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * mask
        denom = jnp.maximum(mask.sum(), 1.0)
        loss = ce.sum() / denom
        zloss = 1e-4 * jnp.square(lse * mask).sum() / denom  # logit drift guard
        return loss + zloss + aux, {"ce": loss, "aux": aux, "tokens": denom}

    # ----------------------------------------------------------- prefill --
    def prefill(self, params, batch):
        """Full-sequence forward building the decode cache.
        Returns (last_logits (B, vocab), cache)."""
        cfg = self.cfg
        assert not cfg.is_encdec or "src_frames" in batch
        cache: Dict[str, Any] = {}
        if cfg.is_encdec:
            enc_h = batch["src_frames"].astype(_dtype(cfg))
            B, Se = enc_h.shape[:2]
            enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None], (B, Se))
            enc_h, _ = self._run_stack(
                params["enc_layers"], enc_h, enc_pos, causal=False,
                windows=jnp.full((cfg.enc_layers,), GLOBAL_WINDOW, jnp.int32),
            )
            enc_h = L.rmsnorm(enc_h, params["enc_ln_f"]["scale"], cfg.norm_eps)
            cache["enc_out"] = enc_h
            cache["enc_pos"] = enc_pos
        h, positions, n_prefix = self._embed_inputs(params, batch)
        B, S = h.shape[:2]

        windows = np.asarray(self._layer_windows())
        if self._uniform_cache:
            # scan over stacked layer params; lax.scan stacks the caches
            def body(hh, p):
                hh, lc = self._prefill_block(
                    p, hh, positions, int(windows[0]),
                    enc_out=cache.get("enc_out"), enc_pos=cache.get("enc_pos"),
                )
                return hh, lc

            h, lcaches = jax.lax.scan(body, h, params["layers"])
        else:
            # per-layer unrolled pass (hybrid: per-layer cache shapes differ)
            lcaches = []
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                h, lc = self._prefill_block(
                    p, h, positions, int(windows[i]),
                    enc_out=cache.get("enc_out"), enc_pos=cache.get("enc_pos"),
                )
                lcaches.append(lc)
        cache["layers"] = lcaches
        cache["pos"] = jnp.full((B,), S, jnp.int32)
        h = L.rmsnorm(h, params["ln_f"]["scale"], cfg.norm_eps)
        logits = L.unembed(params["embed"], cfg, h[:, -1:]).astype(jnp.float32)
        return L.mask_pad_logits(cfg, logits[:, 0]), cache

    def _prefill_block(self, p, x, positions, window, enc_out=None, enc_pos=None):
        cfg = self.cfg
        B, S, D = x.shape
        Kh, dh = cfg.kv_heads, cfg.head_dim
        if cfg.kind == "rwkv":
            h = L.rmsnorm(x, p["ln1"]["scale"], cfg.norm_eps)
            # rerun projections to harvest terminal state (reference path)
            r, k, v, g, logw = RWKV._projections(p["mix"], cfg, h, RWKV._shift(h))
            out = RWKV.time_mix(p["mix"], cfg, h, use_kernel=cfg.use_pallas)
            x = x + out
            # terminal state via chunked scan replay
            rh = RWKV._heads(cfg, r).astype(jnp.float32)
            kh = RWKV._heads(cfg, k).astype(jnp.float32)
            vh = RWKV._heads(cfg, v).astype(jnp.float32)
            wh = RWKV._heads(cfg, logw)
            S_fin = _rwkv_final_state(rh, kh, vh, wh)
            h2 = L.rmsnorm(x, p["ln2"]["scale"], cfg.norm_eps)
            x = x + RWKV.channel_mix(p["mix"], cfg, h2)
            lc = {"S": S_fin, "x_last_tm": h[:, -1], "x_last_cm": h2[:, -1]}
            return x, lc

        h = L.rmsnorm(x, p["ln1"]["scale"], cfg.norm_eps)
        # compute and cache K/V for the whole prefix
        src = h
        k = (src @ p["attn"]["wk"])
        v = (src @ p["attn"]["wv"])
        if cfg.qkv_bias:
            k, v = k + p["attn"]["bk"], v + p["attn"]["bv"]
        k = L.rope(k.reshape(B, S, Kh, dh), positions, cfg.rope_theta)
        v = v.reshape(B, S, Kh, dh)
        w = None if window >= (1 << 29) else window
        attn_out = L.attention(p["attn"], cfg, h, positions, layer_window=w)
        lc = {}
        if w is None:
            lc["k"], lc["v"], lc["kpos"] = k, v, positions
        else:  # sliding window: keep only the last `window` entries
            lc["k"], lc["v"] = k[:, -w:], v[:, -w:]
            lc["kpos"] = positions[:, -w:]
        if cfg.kind == "hybrid":
            ssm_out = SSM.ssm_branch(p["ssm"], cfg, h)
            attn_out = 0.5 * (
                L.rmsnorm(attn_out, p["bn_a"]["scale"], cfg.norm_eps)
                + L.rmsnorm(ssm_out, p["bn_s"]["scale"], cfg.norm_eps)
            )
            lc["ssm"] = _ssm_final_state(p["ssm"], cfg, h)
        x = x + attn_out
        if enc_out is not None:
            hx = L.rmsnorm(x, p["ln_x"]["scale"], cfg.norm_eps)
            x = x + L.attention(
                p["xattn"], cfg, hx, positions, kv=enc_out, kv_positions=enc_pos
            )
        h2 = L.rmsnorm(x, p["ln2"]["scale"], cfg.norm_eps)
        if cfg.kind == "moe":
            mo, _ = MOE.moe_ffn(p["moe"], cfg, h2, capacity_factor=4.0)
            x = x + mo
        else:
            x = x + L.mlp(p["mlp"], cfg, h2)
        return x, lc

    # ------------------------------------------------------------ decode --
    def decode_step(self, params, cache, tokens):
        """One token for every sequence.  tokens: (B,) → (logits, cache)."""
        cfg = self.cfg
        B = tokens.shape[0]
        pos = cache["pos"]
        h = L.embed(params["embed"], tokens[:, None])
        windows = np.asarray(self._layer_windows())
        if self._uniform_cache:
            def body(hh, inp):
                p, lc = inp
                hh, new_lc = self._decode_block(
                    p, hh, lc, pos, int(windows[0]),
                    enc_out=cache.get("enc_out"), enc_pos=cache.get("enc_pos"),
                )
                return hh, new_lc

            h, new_layers = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
        else:
            new_layers = []
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                h, lc = self._decode_block(
                    p, h, cache["layers"][i], pos, int(windows[i]),
                    enc_out=cache.get("enc_out"), enc_pos=cache.get("enc_pos"),
                )
                new_layers.append(lc)
        h = L.rmsnorm(h, params["ln_f"]["scale"], cfg.norm_eps)
        logits = L.mask_pad_logits(
            cfg, L.unembed(params["embed"], cfg, h).astype(jnp.float32)[:, 0]
        )
        new_cache = dict(cache)
        new_cache["layers"] = new_layers
        new_cache["pos"] = pos + 1
        return logits, new_cache

    def _decode_block(self, p, x, lc, pos, window, enc_out=None, enc_pos=None):
        cfg = self.cfg
        B = x.shape[0]
        if cfg.kind == "rwkv":
            h = L.rmsnorm(x, p["ln1"]["scale"], cfg.norm_eps)
            out, st = RWKV.time_mix_step(
                p["mix"], cfg, h, {"S": lc["S"], "x_last": lc["x_last_tm"]}
            )
            x = x + out
            h2 = L.rmsnorm(x, p["ln2"]["scale"], cfg.norm_eps)
            x = x + RWKV.channel_mix(p["mix"], cfg, h2, x_last=lc["x_last_cm"])
            return x, {"S": st["S"], "x_last_tm": h[:, 0], "x_last_cm": h2[:, 0]}

        h = L.rmsnorm(x, p["ln1"]["scale"], cfg.norm_eps)
        w = None if window >= (1 << 29) else window
        attn_out, k_new, v_new = L.decode_attention(
            p["attn"], cfg, h, lc["k"], lc["v"], lc["kpos"], pos, layer_window=w
        )
        if w is None:
            slot = pos[0] % lc["k"].shape[1]
            k_cache = jax.lax.dynamic_update_slice_in_dim(lc["k"], k_new, slot, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(lc["v"], v_new, slot, 1)
            kpos = jax.lax.dynamic_update_slice_in_dim(
                lc["kpos"], pos[:, None], slot, 1
            )
        else:  # ring buffer for sliding window
            k_cache = jnp.concatenate([lc["k"][:, 1:], k_new], axis=1)
            v_cache = jnp.concatenate([lc["v"][:, 1:], v_new], axis=1)
            kpos = jnp.concatenate([lc["kpos"][:, 1:], pos[:, None]], axis=1)
        new_lc = {"k": k_cache, "v": v_cache, "kpos": kpos}
        if cfg.kind == "hybrid":
            ssm_out, st = SSM.ssm_step(p["ssm"], cfg, h, lc["ssm"])
            attn_out = 0.5 * (
                L.rmsnorm(attn_out, p["bn_a"]["scale"], cfg.norm_eps)
                + L.rmsnorm(ssm_out, p["bn_s"]["scale"], cfg.norm_eps)
            )
            new_lc["ssm"] = st
        x = x + attn_out
        if enc_out is not None:
            hx = L.rmsnorm(x, p["ln_x"]["scale"], cfg.norm_eps)
            x = x + L.attention(
                p["xattn"], cfg, hx, pos[:, None], kv=enc_out, kv_positions=enc_pos
            )
        h2 = L.rmsnorm(x, p["ln2"]["scale"], cfg.norm_eps)
        if cfg.kind == "moe":
            mo, _ = MOE.moe_ffn(p["moe"], cfg, h2, capacity_factor=4.0)
            x = x + mo
        else:
            x = x + L.mlp(p["mlp"], cfg, h2)
        return x, new_lc

    # ------------------------------------------------------- cache specs --
    def init_cache(self, batch_size: int, max_len: int, src_len: int = 0):
        """Zero-filled decode cache (decode-shape dry-runs start here)."""
        cfg = self.cfg
        dt = _dtype(cfg)
        B, Kh, dh = batch_size, cfg.kv_heads, cfg.head_dim
        windows = np.asarray(self._layer_windows())
        cache: Dict[str, Any] = {
            "pos": jnp.full((B,), max_len, jnp.int32),
        }
        if cfg.is_encdec:
            cache["enc_out"] = jnp.zeros((B, src_len, cfg.d_model), dt)
            cache["enc_pos"] = jnp.zeros((B, src_len), jnp.int32)
        def one_layer(i):
            if cfg.kind == "rwkv":
                H = cfg.d_model // cfg.rwkv_head_size
                hs = cfg.rwkv_head_size
                return {
                    "S": jnp.zeros((B, H, hs, hs), jnp.float32),
                    "x_last_tm": jnp.zeros((B, cfg.d_model), dt),
                    "x_last_cm": jnp.zeros((B, cfg.d_model), dt),
                }
            w = int(windows[i])
            span = max_len if w >= (1 << 29) else min(w, max_len)
            lc = {
                "k": jnp.zeros((B, span, Kh, dh), dt),
                "v": jnp.zeros((B, span, Kh, dh), dt),
                "kpos": jnp.broadcast_to(
                    jnp.arange(max_len - span, max_len, dtype=jnp.int32)[None],
                    (B, span),
                ),
            }
            if cfg.kind == "hybrid":
                H = cfg.ssm_heads or cfg.n_heads
                P = (cfg.n_heads * cfg.head_dim) // H
                lc["ssm"] = {
                    "h": jnp.zeros((B, H, cfg.ssm_state, P), jnp.float32),
                    "conv": jnp.zeros((B, 4, cfg.n_heads * cfg.head_dim), dt),
                }
            return lc

        if self._uniform_cache:  # stacked (L, ...) pytree, scan-compatible
            lc = one_layer(0)
            cache["layers"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), lc
            )
        else:
            cache["layers"] = [one_layer(i) for i in range(cfg.n_layers)]
        return cache


def _rwkv_final_state(r, k, v, logw):
    """Terminal WKV state after a full sequence (B,S,H,hs)→(B,H,hs,hs)."""
    cum = jnp.cumsum(logw, axis=1)
    total = cum[:, -1:]
    kW = k * jnp.exp(jnp.clip(total - cum, -60.0, 0.0))
    return jnp.einsum("bshk,bshd->bhkd", kW, v)


def _ssm_final_state(p, cfg, u):
    """Terminal SSM state + conv tail for hybrid prefill."""
    x, Bm, Cm, dt, loga = SSM._inputs(p, cfg, u)
    cum = jnp.cumsum(loga, axis=1)
    total = cum[:, -1:]
    w = jnp.exp(jnp.clip(total - cum, -60.0, 0.0))
    h = jnp.einsum("bshn,bsh,bsh,bshp->bhnp", Bm, dt, w, x)
    d_inner = cfg.n_heads * cfg.head_dim
    xin = (u @ p["wx"])[:, -4:]                       # last ≤4 raw conv inputs
    pad = jnp.zeros((u.shape[0], max(0, 4 - xin.shape[1]), d_inner), xin.dtype)
    return {"h": h, "conv": jnp.concatenate([pad, xin], 1)}
