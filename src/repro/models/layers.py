"""Transformer building blocks: norms, RoPE, GQA attention (blockwise
online-softmax — the jnp form of the flash kernel in kernels/), MLPs,
embeddings.  All matmul weights are plain jnp arrays in dict pytrees;
sharding is annotated externally (distributed/sharding.py) so the same
model code runs single-host smoke tests and 512-chip dry-runs.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

Init = jax.nn.initializers


def _dense_init(key, shape, dtype, scale=1.0):
    fan_in = shape[-2] if len(shape) > 1 else shape[0]
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


# ----------------------------------------------------------------- norms --

def rmsnorm(x, w, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


# ------------------------------------------------------------------ rope --

def rope(x, positions, theta):
    """x: (..., S, N, dh) rotary over last dim; positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention --

def init_attention(key, cfg: ModelConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    D, N, Kh, dh = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    p = {
        "wq": _dense_init(k1, (D, N * dh), dtype),
        "wk": _dense_init(k2, (D, Kh * dh), dtype),
        "wv": _dense_init(k3, (D, Kh * dh), dtype),
        "wo": _dense_init(k4, (N * dh, D), dtype, scale=1.0 / np.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((N * dh,), dtype)
        p["bk"] = jnp.zeros((Kh * dh,), dtype)
        p["bv"] = jnp.zeros((Kh * dh,), dtype)
    return p


GLOBAL_WINDOW = 1 << 30   # sentinel window = "full attention" (traced-safe)


def _attn_mask(qp, kp, causal, window):
    """(B, Sq, Sk) validity mask from absolute positions (pad = -1/INT_MAX).
    window is an int32 (possibly traced, e.g. scanned per-layer); a value
    ≥ GLOBAL_WINDOW means unrestricted."""
    mask = (qp[:, :, None] >= 0) & (kp[:, None, :] >= 0) & (
        kp[:, None, :] < jnp.iinfo(jnp.int32).max
    )
    if causal:
        mask &= qp[:, :, None] >= kp[:, None, :]
    mask &= qp[:, :, None] - kp[:, None, :] < window
    return mask


def _block_attn(q, k, v, q_pos, kv_pos, causal, window, q_chunk, kv_chunk):
    """Blockwise online-softmax attention with a flash-style custom VJP.

    Plain autodiff through the fwd scans would save every (q_block ×
    kv_block) score tensor — the exact memory blowup FlashAttention's
    backward avoids; the custom bwd recomputes scores per kv block and
    accumulates dq/dk/dv instead (memory O(S·chunk), jnp reference of
    kernels/flash_attention).  Shapes:
      q: (B, Sq, N, dh), k/v: (B, Sk, Kh, dh), GQA via head grouping.
    """
    static_window = window if isinstance(window, int) and window < (1 << 29) \
        and causal else None
    w = jnp.asarray(GLOBAL_WINDOW if window is None else window, jnp.int32)
    return _block_attn_core(q, k, v, q_pos, kv_pos, w, causal,
                            q_chunk, kv_chunk, static_window)


@partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def _block_attn_core(q, k, v, q_pos, kv_pos, window, causal, q_chunk, kv_chunk,
                     static_window=None):
    out, _ = _block_attn_fwd(q, k, v, q_pos, kv_pos, causal, window,
                             q_chunk, kv_chunk, static_window)
    return out


def _block_attn_vjp_fwd(q, k, v, q_pos, kv_pos, window, causal, q_chunk,
                        kv_chunk, static_window=None):
    out, lse = _block_attn_fwd(q, k, v, q_pos, kv_pos, causal, window,
                               q_chunk, kv_chunk, static_window)
    return out, (q, k, v, q_pos, kv_pos, window, out, lse)


def _block_attn_vjp_bwd(causal, q_chunk, kv_chunk, static_window, res, dout):
    """Flash backward: scan kv blocks, recompute p = exp(s − lse).
    With a static window only the q-span [j·kc, j·kc + kc + w) can have
    nonzero ds for kv block j — sliced dynamically (clamped slices stay
    correct: masks are position-based)."""
    q, k, v, q_pos, kv_pos, window, out, lse = res
    B, Sq, N, dh = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    G = N // Kh
    scale = 1.0 / np.sqrt(dh)
    qg = (q * scale).reshape(B, Sq, Kh, G, dh).astype(jnp.float32)
    dog = dout.reshape(B, Sq, Kh, G, dh).astype(jnp.float32)
    outg = out.reshape(B, Sq, Kh, G, dh).astype(jnp.float32)
    D = jnp.sum(dog * outg, axis=-1)                       # (B,Sq,Kh,G)

    nk = max(1, -(-Sk // kv_chunk))
    kc = -(-Sk // nk)
    pad_k = nk * kc - Sk
    kp = kv_pos
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kp = jnp.pad(kp, ((0, 0), (0, pad_k)),
                     constant_values=jnp.iinfo(jnp.int32).max)
    kb = kf.reshape(B, nk, kc, Kh, dh).swapaxes(0, 1)
    vb = vf.reshape(B, nk, kc, Kh, dh).swapaxes(0, 1)
    kpb = kp.reshape(B, nk, kc).swapaxes(0, 1)

    Dt = D.transpose(0, 2, 3, 1)                            # (B,Kh,G,Sq)

    if static_window is None:
        def body(dq_acc, inp):
            ki, vi, kpi = inp                               # (B,kc,Kh,dh)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ki)
            mask = _attn_mask(q_pos, kpi, causal, window)
            s = jnp.where(mask[:, None, None], s, -1e30)
            p = jnp.exp(s - lse[..., None])                 # (B,Kh,G,Sq,kc)
            dv = jnp.einsum("bhgqk,bqhgd->bkhd", p, dog)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dog, vi)
            ds = p * (dp - Dt[..., None])
            dq_acc = dq_acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds, ki)
            dk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qg)    # qg pre-scaled
            return dq_acc, (dk, dv)

        dq0 = jnp.zeros((B, Sq, Kh, G, dh), jnp.float32)
        dq, (dks, dvs) = jax.lax.scan(body, dq0, (kb, vb, kpb))
    else:
        SPAN = min(Sq, (-(-(kc + static_window) // 128) + 1) * 128)

        def body(dq_acc, inp):
            j, ki, vi, kpi = inp
            start = jnp.maximum(j * kc, 0)                  # clamped by ds
            qg_s = jax.lax.dynamic_slice_in_dim(qg, start, SPAN, 1)
            dog_s = jax.lax.dynamic_slice_in_dim(dog, start, SPAN, 1)
            qp_s = jax.lax.dynamic_slice_in_dim(q_pos, start, SPAN, 1)
            lse_s = jax.lax.dynamic_slice_in_dim(lse, start, SPAN, 3)
            Dt_s = jax.lax.dynamic_slice_in_dim(Dt, start, SPAN, 3)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qg_s, ki)
            mask = _attn_mask(qp_s, kpi, causal, window)
            s = jnp.where(mask[:, None, None], s, -1e30)
            p = jnp.exp(s - lse_s[..., None])
            dv = jnp.einsum("bhgqk,bqhgd->bkhd", p, dog_s)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dog_s, vi)
            ds = p * (dp - Dt_s[..., None])
            dq_c = jnp.einsum("bhgqk,bkhd->bqhgd", ds, ki)
            cur = jax.lax.dynamic_slice_in_dim(dq_acc, start, SPAN, 1)
            dq_acc = jax.lax.dynamic_update_slice_in_dim(
                dq_acc, cur + dq_c, start, 1)
            dk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qg_s)
            return dq_acc, (dk, dv)

        dq0 = jnp.zeros((B, Sq, Kh, G, dh), jnp.float32)
        dq, (dks, dvs) = jax.lax.scan(
            body, dq0, (jnp.arange(nk), kb, vb, kpb))
    dq = (dq * scale).reshape(B, Sq, N, dh).astype(q.dtype)
    dk = dks.swapaxes(0, 1).reshape(B, nk * kc, Kh, dh)[:, :Sk].astype(k.dtype)
    dv = dvs.swapaxes(0, 1).reshape(B, nk * kc, Kh, dh)[:, :Sk].astype(v.dtype)
    return dq, dk, dv, None, None, None


_block_attn_core.defvjp(_block_attn_vjp_fwd, _block_attn_vjp_bwd)


def _block_attn_fwd(q, k, v, q_pos, kv_pos, causal, window, q_chunk, kv_chunk,
                    static_window=None):
    """Forward online-softmax pass; returns (out, lse).  With a static
    window each q block gathers only the ≤ ⌈(qc+w)/kc⌉+1 kv blocks that
    intersect its band (out-of-range gathers land on INT_MAX positions
    → masked)."""
    B, Sq, N, dh = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    G = N // Kh
    scale = 1.0 / np.sqrt(dh)
    q = (q * scale).reshape(B, Sq, Kh, G, dh)

    nq = max(1, -(-Sq // q_chunk))
    q_chunk = -(-Sq // nq)
    nk = max(1, -(-Sk // kv_chunk))
    kv_chunk = -(-Sk // nk)
    pad_q = nq * q_chunk - Sq
    pad_k = nk * kv_chunk - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_k)), constant_values=jnp.iinfo(jnp.int32).max)

    qb = q.reshape(B, nq, q_chunk, Kh, G, dh)
    kb = k.reshape(B, nk, kv_chunk, Kh, dh)
    vb = v.reshape(B, nk, kv_chunk, Kh, dh)
    qpb = q_pos.reshape(B, nq, q_chunk)
    kpb = kv_pos.reshape(B, nk, kv_chunk)

    nb_local = nk if static_window is None else min(
        nk, -(-(q_chunk + static_window) // kv_chunk) + 1)

    def per_qblock(bi, qi, qp):
        # bi: q-block index; qi: (B, qc, Kh, G, dh); qp: (B, qc)
        def body(carry, inp):
            acc, m, l = carry
            ki, vi, kp = inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, ki).astype(jnp.float32)
            mask = _attn_mask(qp, kp, causal, window)
            s = jnp.where(mask[:, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vi.dtype), vi
            ).astype(jnp.float32)
            return (acc_new, m_new, l_new), None

        if static_window is None:
            kbs, vbs, kps = kb, vb, kpb
        else:  # gather only the banded kv blocks for this q block
            last = jnp.minimum(((bi + 1) * q_chunk - 1) // kv_chunk, nk - 1)
            kidx = last - nb_local + 1 + jnp.arange(nb_local)
            kbs = jnp.take(kb, jnp.clip(kidx, 0, nk - 1), axis=1)
            vbs = jnp.take(vb, jnp.clip(kidx, 0, nk - 1), axis=1)
            kps = jnp.where(
                ((kidx >= 0) & (kidx < nk))[None, :, None],
                jnp.take(kpb, jnp.clip(kidx, 0, nk - 1), axis=1),
                jnp.iinfo(jnp.int32).max,
            )
        qc = qi.shape[1]
        acc0 = jnp.zeros((B, Kh, G, qc, dh), jnp.float32)
        m0 = jnp.full((B, Kh, G, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Kh, G, qc), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            body, (acc0, m0, l0),
            (kbs.swapaxes(0, 1), vbs.swapaxes(0, 1), kps.swapaxes(0, 1)),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))            # (B,Kh,G,qc)
        return out.transpose(0, 3, 1, 2, 4), lse            # (B,qc,Kh,G,dh)

    out, lse = jax.lax.map(
        lambda args: per_qblock(*args),
        (jnp.arange(nq), qb.swapaxes(0, 1), qpb.swapaxes(0, 1)),
    )  # (nq, B, qc, Kh, G, dh), (nq, B, Kh, G, qc)
    out = out.swapaxes(0, 1).reshape(B, nq * q_chunk, Kh * G * dh)
    lse = lse.transpose(1, 2, 3, 0, 4).reshape(B, Kh, G, nq * q_chunk)
    return out[:, :Sq], lse[..., :Sq]


def attention(p, cfg: ModelConfig, x, positions, *, layer_window=None,
              causal=True, kv=None, kv_positions=None):
    """Self- (or cross-, when kv is given) attention.

    x: (B, S, D); kv: optional (B, Sk, D) encoder output for cross-attn.
    """
    B, S, D = x.shape
    N, Kh, dh = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    src = x if kv is None else kv
    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, N, dh)
    k = k.reshape(B, src.shape[1], Kh, dh)
    v = v.reshape(B, src.shape[1], Kh, dh)
    kv_pos = kv_positions if kv_positions is not None else positions
    if kv is None:  # rope only for self-attention
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_pos, cfg.rope_theta)
    out = _block_attn(
        q, k, v, positions, kv_pos, causal and kv is None, layer_window,
        cfg.q_chunk, cfg.kv_chunk,
    )
    return out.astype(x.dtype) @ p["wo"]


def decode_attention(p, cfg: ModelConfig, x, cache_k, cache_v, kpos, pos, *,
                     layer_window=None):
    """Single-token decode against a (B, S_max, Kh, dh) KV cache.

    kpos: (B, S_max) the *absolute position* stored in each cache slot
    (-1 = empty) — ring buffers and sliding windows mask exactly like the
    training path.  pos: (B,) current position.
    Returns (out, new_k_entry, new_v_entry) — cache update done by caller.
    """
    B, S, D = x.shape
    assert S == 1
    N, Kh, dh = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = rope(q.reshape(B, 1, N, dh), pos[:, None], cfg.rope_theta)
    k = rope(k.reshape(B, 1, Kh, dh), pos[:, None], cfg.rope_theta)
    v = v.reshape(B, 1, Kh, dh)

    valid = (kpos >= 0) & (kpos < pos[:, None])
    if layer_window is not None:
        valid &= (pos[:, None] - kpos) < layer_window
    G = N // Kh
    qg = q.reshape(B, Kh, G, dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, cache_k).astype(jnp.float32)
    s = s / np.sqrt(dh)
    # current token attends to itself too
    s_self = jnp.einsum("bhgd,bshd->bhgs", qg, k).astype(jnp.float32) / np.sqrt(dh)
    s = jnp.where(valid[:, None, None], s, -1e30)
    m = jnp.maximum(s.max(-1), s_self[..., 0])
    p_cache = jnp.exp(s - m[..., None])
    p_self = jnp.exp(s_self[..., 0] - m)
    denom = p_cache.sum(-1) + p_self
    out = jnp.einsum("bhgs,bshd->bhgd", p_cache.astype(cache_v.dtype), cache_v).astype(jnp.float32)
    out = out + p_self[..., None] * v[:, 0, :, None].astype(jnp.float32)
    out = (out / denom[..., None]).reshape(B, 1, N * dh)
    return out.astype(x.dtype) @ p["wo"], k, v


# ------------------------------------------------------------------- mlp --

def init_mlp(key, cfg: ModelConfig, dtype, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": _dense_init(k1, (cfg.d_model, d_ff), dtype),
        "w_down": _dense_init(k2, (d_ff, cfg.d_model), dtype,
                              scale=1.0 / np.sqrt(2 * cfg.n_layers)),
    }
    if cfg.act == "swiglu":
        p["w_gate"] = _dense_init(k3, (cfg.d_model, d_ff), dtype)
    return p


def mlp(p, cfg: ModelConfig, x):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


# ------------------------------------------------------------ embeddings --

def init_embed(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    V = cfg.padded_vocab
    p = {"tok": (jax.random.normal(k1, (V, cfg.d_model)) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["head"] = _dense_init(k2, (cfg.d_model, V), dtype)
    return p


def embed(p, tokens):
    """Token embedding.

    Under a sharded mesh the lookup is a one-hot matmul: XLA's SPMD
    partitioner handles a (tokens, V) × (V, D) dot over a sharded table
    cleanly (and on the MXU it's fast), whereas a vocab- or D-sharded
    gather either trips verifier bugs or triggers involuntary full
    rematerialization.  Single-device (smoke tests, CPU examples) keeps
    the plain gather.
    """
    from jax.interpreters import pxla

    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty or mesh.size == 1:
        return jnp.take(p["tok"], tokens, axis=0)
    tok = p["tok"]
    oh = jax.nn.one_hot(tokens, tok.shape[0], dtype=tok.dtype)
    return oh @ tok


def unembed(p, cfg: ModelConfig, x):
    """Logits over the *padded* vocab; callers mask ids ≥ cfg.vocab."""
    if cfg.tie_embeddings:
        return x @ p["tok"].T
    return x @ p["head"]


def mask_pad_logits(cfg: ModelConfig, logits):
    ids = jnp.arange(logits.shape[-1])
    return jnp.where(ids < cfg.vocab, logits, -1e30)
