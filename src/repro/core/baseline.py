"""Materialized-join greedy boosted trees — the paper's comparison baseline.

Standard in-memory gradient boosting on the design matrix X = cols(J):
the algorithm every library implements, and the oracle our relational
Algorithms 1/2 must match split-for-split (tests assert prediction
equality).  Scoring is the identical argmax(S_L²/n_L + S_R²/n_R) form.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .trainer import BoostConfig
from .tree import TreeArrays


@dataclasses.dataclass
class MaterializedBooster:
    X: jnp.ndarray            # (n, d) in global-feature-id order
    y: jnp.ndarray            # (n,)
    cfg: BoostConfig

    def __post_init__(self):
        Xn = np.asarray(self.X)
        self._order = jnp.asarray(np.argsort(Xn, axis=0, kind="stable").T)  # (d, n)
        self._svals = jnp.asarray(np.take_along_axis(Xn, np.asarray(self._order).T, 0).T)

    def _best_split(self, idx, r, K):
        """idx: (n,) node assignment; r: residuals.  Returns per-node best."""
        n, d = self.X.shape
        onehot = jax.nn.one_hot(idx, K, dtype=jnp.float32)          # (n, K)

        def one_feature(fi):
            o = self._order[fi]
            vals = self._svals[fi]
            oh = jnp.take(onehot, o, axis=0)                        # (n, K)
            rs = jnp.take(r, o)
            cn = jnp.cumsum(oh, axis=0).T                           # (K, n)
            cs = jnp.cumsum(oh * rs[:, None], axis=0).T
            tot_n, tot_s = cn[:, -1], cs[:, -1]
            nl, sl = cn[:, :-1], cs[:, :-1]
            nr, sr = tot_n[:, None] - nl, tot_s[:, None] - sl
            valid = (vals[1:] > vals[:-1])[None] & (nl > 0) & (nr > 0)
            score = jnp.where(
                valid,
                jnp.square(sl) / jnp.maximum(nl, 1e-9)
                + jnp.square(sr) / jnp.maximum(nr, 1e-9),
                -jnp.inf,
            )
            p = jnp.argmax(score, axis=1)
            take = lambda a: jnp.take_along_axis(a, p[:, None], 1)[:, 0]
            base = jnp.square(tot_s) / jnp.maximum(tot_n, 1e-9)
            return (
                take(score) - base,
                jnp.take(vals[1:], p),
                take(sl), take(nl), take(sr), take(nr),
            )

        res = jax.lax.map(one_feature, jnp.arange(d))
        key = res[0] - 1e-9 * jnp.arange(d, dtype=jnp.float32)[:, None]
        f = jnp.argmax(key, axis=0)
        take = lambda a: jnp.take_along_axis(a, f[None], 0)[0]
        return f.astype(jnp.int32), *(take(a) for a in res)

    def fit(self) -> List[TreeArrays]:
        cfg = self.cfg
        trees: List[TreeArrays] = []
        pred = jnp.zeros_like(self.y)
        for _ in range(cfg.n_trees):
            r = self.y - pred
            tree = TreeArrays.empty(cfg.depth)
            idx = jnp.zeros((self.X.shape[0],), jnp.int32)
            node_mean = jnp.zeros((1,), jnp.float32)
            for level in range(cfg.depth):
                K = 2 ** level
                f, score, thr, sl, nl, sr, nr = self._best_split(idx, r, K)
                valid = jnp.isfinite(score) & (score > cfg.min_gain)
                feat = jnp.where(valid, f, -1).astype(jnp.int32)
                th = jnp.where(valid, thr, jnp.inf)
                start = K - 1
                tree = TreeArrays(
                    feat=jax.lax.dynamic_update_slice_in_dim(tree.feat, feat, start, 0),
                    thr=jax.lax.dynamic_update_slice_in_dim(tree.thr, th, start, 0),
                    leaf=tree.leaf,
                )
                lm = jnp.where(valid, sl / jnp.maximum(nl, 1e-9), node_mean)
                rm = jnp.where(valid, sr / jnp.maximum(nr, 1e-9), node_mean)
                node_mean = jnp.stack([lm, rm], 1).reshape(-1)
                fv = jnp.take(feat, idx)
                tv = jnp.take(th, idx)
                xv = jnp.take_along_axis(self.X, jnp.maximum(fv, 0)[:, None], 1)[:, 0]
                idx = 2 * idx + ((xv >= tv) & (fv >= 0)).astype(jnp.int32)
            tree = TreeArrays(feat=tree.feat, thr=tree.thr, leaf=cfg.lr * node_mean)
            trees.append(tree)
            pred = pred + jnp.take(tree.leaf, idx)
        return trees
