"""Commutative semiring abstraction for SumProd queries.

A SumProd query ``⊕_{x∈J} ⊗_f q_f(x_f)`` (paper §1.1.1) is generic over a
commutative semiring ``(S, ⊕, ⊗)``.  Every semiring here represents an
element of S as a jnp array whose *trailing* ``value_shape`` dims hold the
element; leading dims are batch dims (rows, tree nodes, leaves, ...).

Implemented semirings
---------------------
- :class:`Arithmetic`    — (R, +, ·): counts / sums / products.
- :class:`Channels`      — (R^c, +, ⊙): c independent arithmetic channels.
  Used to fuse the paper's three queries (count, Σy, Σy²) into one pass.
- :class:`PolyCoeff`     — (R^k, +, ·mod z^k): the paper's tensor-sketch
  polynomial semiring in *coefficient* space; ⊗ = circular convolution
  (computed via FFT, the paper's O(k log k) form).
- :class:`PolyFreq`      — rfft image of PolyCoeff; ⊗ = elementwise complex
  product (O(k)).  Beyond-paper optimization (Pham–Pagh frequency trick):
  sketches stay in the frequency domain end-to-end.
- :class:`Tropical`      — (R∪{+inf}, min, +): used by property tests to
  certify semiring-genericity of the engine (also: cheapest-join-path).
- :class:`BooleanSR`     — ({0,1}, or, and): join emptiness tests.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


class Semiring:
    """Base class.  Elements: arrays [..., *value_shape] of ``dtype``."""

    value_shape: Tuple[int, ...] = ()
    dtype = jnp.float32

    # -- element constructors -------------------------------------------------
    def zeros(self, batch_shape=()):
        raise NotImplementedError

    def ones(self, batch_shape=()):
        raise NotImplementedError

    # -- algebra ---------------------------------------------------------------
    def add(self, a, b):
        raise NotImplementedError

    def mul(self, a, b):
        raise NotImplementedError

    def segment_add(self, vals, segment_ids, num_segments):
        """⊕-reduce rows of ``vals`` (axis 0) by ``segment_ids``.

        Empty segments must yield the ⊕-identity (semiring zero).
        """
        raise NotImplementedError

    def reduce_add(self, vals, axis=0):
        """⊕-reduce along one batch axis."""
        raise NotImplementedError

    # -- helpers ----------------------------------------------------------------
    def _bmask(self, mask):
        """Reshape a batch-shaped boolean mask to broadcast over value dims."""
        return mask.reshape(mask.shape + (1,) * len(self.value_shape))

    def where(self, mask, a, b):
        return jnp.where(self._bmask(mask), a, b)

    def mask(self, vals, keep):
        """Row exclusion: masked-out rows become semiring zero (paper: a row
        failing a J^{(v)} constraint contributes the ⊕-identity)."""
        return self.where(keep, vals, self.zeros(keep.shape))

    def scale(self, vals, scalars):
        """Multiply semiring values by *real* scalars.  Valid whenever ⊕ is
        ordinary + (S is then an R-module): Arithmetic/Channels/Poly/Freq."""
        raise NotImplementedError


class _ModuleSemiring(Semiring):
    """Shared impl for semirings whose ⊕ is elementwise +."""

    def zeros(self, batch_shape=()):
        return jnp.zeros(tuple(batch_shape) + self.value_shape, self.dtype)

    def add(self, a, b):
        return a + b

    def segment_add(self, vals, segment_ids, num_segments):
        return jax.ops.segment_sum(vals, segment_ids, num_segments=num_segments)

    def reduce_add(self, vals, axis=0):
        return jnp.sum(vals, axis=axis)

    def scale(self, vals, scalars):
        return vals * scalars.reshape(scalars.shape + (1,) * len(self.value_shape)).astype(vals.dtype)


@dataclasses.dataclass(frozen=True)
class Arithmetic(_ModuleSemiring):
    value_shape: Tuple[int, ...] = ()
    dtype = jnp.float32

    def ones(self, batch_shape=()):
        return jnp.ones(tuple(batch_shape), self.dtype)

    def mul(self, a, b):
        return a * b


@dataclasses.dataclass(frozen=True)
class Channels(_ModuleSemiring):
    """c independent arithmetic channels: ⊗ is elementwise per channel.

    The paper's node statistics (n, Σy, Σy²) are three SumProd queries whose
    per-feature terms differ only at the label column — they fuse into one
    pass over the (R^3, +, ⊙) product semiring.

    ``dtype`` is configurable: the serving factors are 0/1 leaf masks, so
    bf16 channels halve factor memory/bandwidth at a bounded count error
    (see serving/compile.py ``factor_dtype``).
    """

    channels: int = 3
    dtype: "jnp.dtype" = jnp.float32

    @property
    def value_shape(self):  # type: ignore[override]
        return (self.channels,)

    def ones(self, batch_shape=()):
        return jnp.ones(tuple(batch_shape) + (self.channels,), self.dtype)

    def mul(self, a, b):
        return a * b


@dataclasses.dataclass(frozen=True)
class PolyCoeff(_ModuleSemiring):
    """Polynomials mod z^k, coefficient representation (paper §3).

    ⊗ = circular convolution, evaluated with real FFTs — the paper's
    O(k log k) bound.  ``k`` must be even (rfft symmetry used by PolyFreq
    round-trips).
    """

    k: int = 64

    def __post_init__(self):
        assert self.k % 2 == 0, "sketch size k must be even"

    @property
    def value_shape(self):  # type: ignore[override]
        return (self.k,)

    def ones(self, batch_shape=()):
        # multiplicative identity: 1·z^0
        out = jnp.zeros(tuple(batch_shape) + (self.k,), self.dtype)
        return out.at[..., 0].set(1.0)

    def mul(self, a, b):
        fa = jnp.fft.rfft(a, n=self.k, axis=-1)
        fb = jnp.fft.rfft(b, n=self.k, axis=-1)
        return jnp.fft.irfft(fa * fb, n=self.k, axis=-1).astype(self.dtype)

    def norm_sq(self, vals):
        return jnp.sum(jnp.square(vals), axis=-1)

    def to_freq(self, vals):
        return jnp.fft.rfft(vals, n=self.k, axis=-1)


@dataclasses.dataclass(frozen=True)
class PolyFreq(_ModuleSemiring):
    """Frequency-domain image of :class:`PolyCoeff` under rfft.

    Elements are the k//2+1 complex rfft coefficients.  ⊕ = + (FFT is
    linear), ⊗ = elementwise complex multiply (convolution theorem).  The
    monomials the sketch inserts have *analytic* transforms
    (s·z^h ↦ s·e^{-2πi·h·j/k}), so no FFT is ever executed — each ⊗ costs
    O(k) instead of the paper's O(k log k).  Final sketch norms use
    Parseval (see :meth:`norm_sq`).
    """

    k: int = 64
    dtype = jnp.complex64

    def __post_init__(self):
        assert self.k % 2 == 0

    @property
    def value_shape(self):  # type: ignore[override]
        return (self.k // 2 + 1,)

    def ones(self, batch_shape=()):
        return jnp.ones(tuple(batch_shape) + (self.k // 2 + 1,), self.dtype)

    def mul(self, a, b):
        return a * b

    def scale(self, vals, scalars):
        return vals * scalars.reshape(scalars.shape + (1,)).astype(self.dtype)

    def norm_sq(self, vals):
        """Parseval for rfft of a real length-k signal:
        ||x||² = (|X_0|² + 2·Σ_{0<j<k/2}|X_j|² + |X_{k/2}|²) / k."""
        p = jnp.square(jnp.abs(vals))
        w = jnp.concatenate(
            [jnp.ones((1,)), 2.0 * jnp.ones((self.k // 2 - 1,)), jnp.ones((1,))]
        ).astype(p.dtype)
        return jnp.sum(p * w, axis=-1) / self.k

    def to_coeff(self, vals):
        return jnp.fft.irfft(vals, n=self.k, axis=-1)


@dataclasses.dataclass(frozen=True)
class Tropical(Semiring):
    """(R ∪ {+inf}, min, +) — min-plus."""

    value_shape: Tuple[int, ...] = ()
    dtype = jnp.float32

    def zeros(self, batch_shape=()):
        return jnp.full(tuple(batch_shape), jnp.inf, self.dtype)

    def ones(self, batch_shape=()):
        return jnp.zeros(tuple(batch_shape), self.dtype)

    def add(self, a, b):
        return jnp.minimum(a, b)

    def mul(self, a, b):
        return a + b

    def segment_add(self, vals, segment_ids, num_segments):
        return jax.ops.segment_min(vals, segment_ids, num_segments=num_segments)

    def reduce_add(self, vals, axis=0):
        return jnp.min(vals, axis=axis)


@dataclasses.dataclass(frozen=True)
class BooleanSR(Semiring):
    """({False,True}, or, and)."""

    value_shape: Tuple[int, ...] = ()
    dtype = jnp.bool_

    def zeros(self, batch_shape=()):
        return jnp.zeros(tuple(batch_shape), self.dtype)

    def ones(self, batch_shape=()):
        return jnp.ones(tuple(batch_shape), self.dtype)

    def add(self, a, b):
        return jnp.logical_or(a, b)

    def mul(self, a, b):
        return jnp.logical_and(a, b)

    def segment_add(self, vals, segment_ids, num_segments):
        return jax.ops.segment_max(vals.astype(jnp.int32), segment_ids, num_segments=num_segments).astype(jnp.bool_)

    def reduce_add(self, vals, axis=0):
        return jnp.any(vals, axis=axis)
