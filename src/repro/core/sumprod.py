"""Inside-out evaluation of SumProd queries (paper §1.1.1, Lemma 1.1).

The evaluator is a vectorized message-passing pass over a rooted join
tree.  Each table contributes a *factor*: one semiring value per row
(``⊗`` of that table's q_f terms, with J^{(v)}-constraint masks already
applied as semiring zeros).  An edge child→parent sends

    msg[key] = ⊕_{rows r of child : key(r)=key} factor_child[r]
    factor_parent[r'] ⊗= msg[key(r')]

computed as one ``segment-⊕`` (dense key dictionary, built statically by
the Schema) plus one gather.  After all edges, the root's factor holds,
per root row ρ, exactly ``⊕_{x ∈ ρ ⋈ J} ⊗_f q_f(x_f)`` — the paper's
*grouped-by* query.  The ungrouped query is one more ⊕-reduce.

TPU adaptation (DESIGN.md §3): the paper runs one inside-out pass per
query; we batch query families (tree nodes, leaves, leaf pairs) with
``vmap`` over the factor arrays — the plan (segment ids) is static.

Distribution: rows shard over the data axes; ``segment-⊕`` runs
per-shard and key-domain message vectors are ⊕-combined across the axis
at emission time.  The combine is ``spmd.psum_message`` — a replicated
sharding constraint that GSPMD lowers to the cross-shard all-reduce —
applied inside :meth:`SumProd.messages` / :meth:`refresh_messages` /
:meth:`messages_memo`, so every caller (serving, boosting, IVM) gets the
same collective point.  With no active data mesh the constraint is an
identity and the single-device program is bit-unchanged.  Edge/query
accounting is host-side and therefore invariant under sharding: a mesh
moves bytes, never work.  (``distributed/collectives.py`` keeps the
explicit shard_map+psum prototype as a reference.)
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Hashable, Iterable, List, Optional, Set

import jax
import jax.numpy as jnp

from ..distributed import spmd as _spmd
from ..obs import metrics as _metrics
from ..obs.trace import span as _span
from .schema import Schema, JoinTree
from .semiring import Semiring


class QueryCounter:
    """Counts SumProd evaluations — used by benchmarks to verify the
    paper's query-complexity claims (O(m²L²τ) exact vs O(mLτ) sketched).

    ``edges`` separately counts segment-⊕ message emissions: a full
    inside-out pass emits one per join-tree edge, while an incremental
    refresh (see :meth:`SumProd.refresh_messages`) emits only along the
    changed tables' root paths — the ratio the IVM benchmarks report.

    Back-compat shim over :mod:`repro.obs.metrics`: bumps come from
    jitted callbacks and benchmark threads, so each instance owns
    thread-safe :class:`~repro.obs.metrics.Counter`s and additionally
    mirrors into the process registry's ``sumprod.queries`` /
    ``sumprod.edges`` series (the aggregate the launch CLIs report).
    ``count``/``edges`` read exactly what this instance accumulated —
    per-counter accounting (the IVM ratios) is unchanged.
    """

    def __init__(self):
        self._count = _metrics.Counter("sumprod.queries")
        self._edges = _metrics.Counter("sumprod.edges")
        reg = _metrics.get_registry()
        self._g_count = reg.counter("sumprod.queries")
        self._g_edges = reg.counter("sumprod.edges")

    @property
    def count(self) -> int:
        return self._count.value

    @property
    def edges(self) -> int:
        return self._edges.value

    def bump(self, n: int = 1):
        self._count.inc(n)
        self._g_count.inc(n)

    def bump_edges(self, n: int = 1):
        self._edges.inc(n)
        self._g_edges.inc(n)


def refresh_plan(jt: JoinTree, dirty: Iterable[int]) -> List[bool]:
    """Static plan of a path-restricted refresh: which edges (leaf-first
    order, aligned with ``jt.edges``) must re-emit their segment-⊕ when
    the tables in ``dirty`` changed.  Dirtiness propagates child→parent,
    so the plan covers the union of the dirty tables' root paths.  Shared
    by the eager :meth:`SumProd.refresh_messages` and the jitted refresh
    cached per (root, dirty-set, shapes) in incremental/maintain.py —
    both must re-emit exactly these edges so ``QueryCounter.edges``
    accounting is route-independent."""
    live: Set[int] = set(dirty)
    plan: List[bool] = []
    for e in jt.edges:
        hit = e.child in live
        plan.append(hit)
        if hit:
            live.add(e.parent)
    return plan


class MessageCache:
    """Signature-keyed memo of per-edge segment-⊕ messages.

    Key: (join-tree root, edge index, subtree signature).  The subtree
    signature combines, bottom-up, the factor signatures of every table
    in the edge's child subtree — two queries whose factors agree on that
    whole subtree share the message, so boosting's per-node/per-leaf
    query families reuse unchanged-subtree messages across tree levels,
    across trees, and across deltas.  Entries are LRU-bounded per edge;
    a cached message whose key domain grew since emission is ⊕-identity
    padded on retrieval (a new key has no child rows yet).
    """

    def __init__(self, max_per_edge: int = 64):
        self.max_per_edge = max_per_edge
        self._store: Dict[tuple, "OrderedDict[Hashable, jnp.ndarray]"] = {}
        self.hits = 0
        self.misses = 0
        reg = _metrics.get_registry()
        self._g_hits = reg.counter("msgcache.hits")
        self._g_misses = reg.counter("msgcache.misses")

    def get(self, root: int, edge: int, sig: Hashable):
        slot = self._store.get((root, edge))
        if slot is None or sig not in slot:
            self.misses += 1
            self._g_misses.inc()
            return None
        slot.move_to_end(sig)
        self.hits += 1
        self._g_hits.inc()
        return slot[sig]

    def put(self, root: int, edge: int, sig: Hashable, msg: jnp.ndarray):
        slot = self._store.setdefault((root, edge), OrderedDict())
        slot[sig] = msg
        slot.move_to_end(sig)
        while len(slot) > self.max_per_edge:
            slot.popitem(last=False)

    def clear(self):
        self._store.clear()

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class SumProd:
    """Executable SumProd program for one schema."""

    def __init__(self, schema: Schema, counter: Optional[QueryCounter] = None):
        self.schema = schema
        self.counter = counter

    def ones_factors(self, sem: Semiring, batch_shape=()) -> Dict[str, jnp.ndarray]:
        """Factor dict with ⊗-identity everywhere (q_f ≡ 1)."""
        return {
            t.name: sem.ones(tuple(batch_shape) + (t.n_rows,))
            for t in self.schema.tables
        }

    # ------------------------------------------------------- message pass --
    def node_factor(
        self,
        sem: Semiring,
        factors: Dict[str, jnp.ndarray],
        jt: JoinTree,
        node: int,
        msgs: List[Optional[jnp.ndarray]],
    ) -> jnp.ndarray:
        """Combined factor at ``node``: base factor ⊗ gathered messages
        from every child edge whose message is already available.  The
        gather axis is derived from each message's rank, so factors and
        messages may carry leading batch dims (broadcast under ⊗)."""
        f = factors[self.schema.names[node]]
        for i, e in enumerate(jt.edges):
            if e.parent == node and msgs[i] is not None:
                m = msgs[i]
                ax = m.ndim - 1 - len(sem.value_shape)
                f = sem.mul(f, jnp.take(m, e.parent_ids, axis=ax))
        return f

    @staticmethod
    def _segment_add_any(sem: Semiring, vals, segment_ids, num_segments):
        """segment-⊕ with an optional leading batch dim (vmapped)."""
        if vals.ndim == 1 + len(sem.value_shape):
            return sem.segment_add(vals, segment_ids, num_segments)
        return jax.vmap(
            lambda v: sem.segment_add(v, segment_ids, num_segments)
        )(vals)

    def messages(
        self,
        sem: Semiring,
        factors: Dict[str, jnp.ndarray],
        root: Optional[str] = None,
        jt: Optional[JoinTree] = None,
    ) -> List[jnp.ndarray]:
        """Full inside-out pass, returning the per-edge segment-⊕ messages
        (leaf-first order, aligned with ``jt.edges``) instead of consuming
        them inline — the cacheable state incremental maintenance reuses."""
        if jt is None:
            jt = self.schema.join_tree(root)
        msgs: List[Optional[jnp.ndarray]] = [None] * len(jt.edges)
        with _span("sumprod.messages", n_edges=len(jt.edges)):
            for i, e in enumerate(jt.edges):
                with _span("sumprod.emit", edge=i, child=e.child,
                           parent=e.parent, n_keys=e.n_keys):
                    cf = self.node_factor(sem, factors, jt, e.child, msgs)
                    msgs[i] = _spmd.psum_message(
                        sem.segment_add(cf, e.child_ids, e.n_keys))
        if self.counter is not None:
            self.counter.bump_edges(len(jt.edges))
        return msgs  # type: ignore[return-value]

    def refresh_messages(
        self,
        sem: Semiring,
        factors: Dict[str, jnp.ndarray],
        msgs: List[jnp.ndarray],
        dirty: Iterable[int],
        jt: JoinTree,
    ) -> List[jnp.ndarray]:
        """Path-restricted re-emission: recompute messages only on edges
        whose child subtree contains a changed table, reusing every cached
        clean message.  ``dirty``: indices of tables whose factors changed.
        Cached messages whose key domain grew since they were emitted are
        ⊕-identity-padded (a previously unseen key has no child rows yet).
        Cost: one segment-⊕ per edge on the union of the dirty tables'
        root paths — O(path) instead of O(τ−1).
        """
        plan = refresh_plan(jt, dirty)
        new = list(msgs)
        with _span("sumprod.refresh", n_edges=sum(plan)):
            for i, e in enumerate(jt.edges):
                if new[i].shape[0] < e.n_keys:
                    pad = sem.zeros((e.n_keys - new[i].shape[0],))
                    new[i] = jnp.concatenate([new[i], pad], axis=0)
                if plan[i]:
                    with _span("sumprod.emit", edge=i, child=e.child,
                               parent=e.parent, n_keys=e.n_keys):
                        cf = self.node_factor(sem, factors, jt, e.child, new)
                        new[i] = _spmd.psum_message(
                            sem.segment_add(cf, e.child_ids, e.n_keys))
        if self.counter is not None:
            self.counter.bump_edges(sum(plan))
        return new

    def messages_memo(
        self,
        sem: Semiring,
        factors: Dict[str, jnp.ndarray],
        jt: JoinTree,
        sigs: Dict[str, Hashable],
        cache: MessageCache,
    ) -> List[jnp.ndarray]:
        """Inside-out message pass through a signature-keyed cache.

        ``factors``: per-table arrays with ONE leading batch dim
        ((B_t, n_rows, *value_shape), B_t ∈ {1, K}) — a query family may
        batch node-uniform tables as a single row and broadcast.
        ``sigs``: per-table hashable factor signatures (content version +
        mask digest + batch width).  An edge whose whole child subtree
        matches a cached signature reuses the cached message and emits
        nothing; only misses run a segment-⊕ (and bump
        ``QueryCounter.edges``) — the maintained-retraining win the
        benchmarks audit.
        """
        names = self.schema.names
        msgs: List[Optional[jnp.ndarray]] = [None] * len(jt.edges)
        subsig: List[Hashable] = [None] * len(jt.edges)
        recomputed = 0
        for i, e in enumerate(jt.edges):
            incoming = [j for j in range(i) if jt.edges[j].parent == e.child]
            sig = (sigs[names[e.child]], tuple(subsig[j] for j in incoming))
            subsig[i] = sig
            hit = cache.get(jt.root, i, sig)
            if hit is not None:
                ax = hit.ndim - 1 - len(sem.value_shape)
                if hit.shape[ax] < e.n_keys:      # key domain grew: ⊕-pad
                    pad_batch = hit.shape[:ax] + (e.n_keys - hit.shape[ax],)
                    hit = jnp.concatenate(
                        [hit, sem.zeros(pad_batch)], axis=ax
                    )
                    cache.put(jt.root, i, sig, hit)
                msgs[i] = hit
                continue
            with _span("sumprod.emit", edge=i, child=e.child,
                       parent=e.parent, n_keys=e.n_keys):
                cf = self.node_factor(sem, factors, jt, e.child, msgs)
                msgs[i] = _spmd.psum_message(
                    self._segment_add_any(sem, cf, e.child_ids, e.n_keys))
            cache.put(jt.root, i, sig, msgs[i])
            recomputed += 1
        if self.counter is not None:
            self.counter.bump_edges(recomputed)
        return msgs  # type: ignore[return-value]

    def __call__(
        self,
        sem: Semiring,
        factors: Dict[str, jnp.ndarray],
        group_by: Optional[str] = None,
        root: Optional[str] = None,
        n_queries: int = 1,
    ):
        """Evaluate the query.

        factors: per-table arrays (n_rows, *value_shape).  Leading batch
        dims are NOT allowed here — use jax.vmap around this call (the
        static plan is shared).
        group_by: if set, return per-row results for that table (the tree
        is rooted there).  Otherwise reduce to a single semiring value.
        """
        root_name = group_by or root or self.schema.names[0]
        jt: JoinTree = self.schema.join_tree(root_name)
        if self.counter is not None:
            self.counter.bump(n_queries)

        msgs = self.messages(sem, factors, jt=jt)
        out = self.node_factor(sem, factors, jt, jt.root, msgs)
        if group_by is not None:
            return out
        return _spmd.replicate(sem.reduce_add(out, axis=0))


def materialize_join(schema: Schema) -> Dict[str, jnp.ndarray]:
    """Materialize J = T_1 ⋈ … ⋈ T_τ (bag semantics) — tests/baseline ONLY.

    Returns {column_name: (|J|,) array} plus per-table row indices
    ``__rows__<table>`` so tests can cross-check grouped queries.
    """
    import numpy as np

    tables = schema.tables
    # start from the first table
    cur_cols = {c: np.asarray(v) for c, v in tables[0].columns.items()}
    cur_rows = {tables[0].name: np.arange(tables[0].n_rows)}
    done = {tables[0].name}
    pending = [t for t in tables[1:]]
    while pending:
        progress = False
        for t in list(pending):
            shared = [c for c in t.columns if c in cur_cols]
            if not shared:
                continue
            # hash-join on shared columns
            left_key = np.stack([cur_cols[c] for c in shared], 1)
            right_key = np.stack([t.col(c) for c in shared], 1)
            uni, li = np.unique(
                np.concatenate([left_key, right_key]), axis=0, return_inverse=True
            )
            lk, rk = li[: len(left_key)], li[len(left_key):]
            # build index lists per key for the right side
            order = np.argsort(rk, kind="stable")
            rk_sorted = rk[order]
            starts = np.searchsorted(rk_sorted, np.arange(len(uni)))
            ends = np.searchsorted(rk_sorted, np.arange(len(uni)), side="right")
            li_out, ri_out = [], []
            for i, key in enumerate(lk):
                for j in order[starts[key]:ends[key]]:
                    li_out.append(i)
                    ri_out.append(j)
            li_out = np.asarray(li_out, np.int64)
            ri_out = np.asarray(ri_out, np.int64)
            cur_cols = {c: v[li_out] for c, v in cur_cols.items()}
            for c in t.columns:
                if c not in cur_cols:
                    cur_cols[c] = t.col(c)[ri_out]
            cur_rows = {k: v[li_out] for k, v in cur_rows.items()}
            cur_rows[t.name] = ri_out
            done.add(t.name)
            pending.remove(t)
            progress = True
        if not progress:
            raise ValueError("disconnected join graph")
    out = {c: jnp.asarray(v) for c, v in cur_cols.items()}
    for k, v in cur_rows.items():
        out["__rows__" + k] = jnp.asarray(v, jnp.int32)
    return out
