"""Injectable grouped-query engines for the boosting trainer.

The trainer's node-statistics queries — the fused (n, Σy, Σy²) channels
query, the exact leaf-pair count queries, and the polynomial-semiring
sketch queries — are routed through a :class:`QueryEngine`, so the SAME
Algorithm 1–3 control flow (level-BFS tree growth, residual statistics,
split ranking) can run against different evaluation strategies:

- :class:`DirectEngine` (here): one full inside-out SumProd pass per
  query family, vmapped over the level's tree nodes — the paper's
  execution model.  Jittable: the whole level step compiles to one XLA
  program, and query/edge costs are accounted analytically.
- ``MaintainedEngine`` (incremental/retrain.py): answers the same
  queries from signature-keyed per-edge message caches kept fresh under
  :class:`~repro.incremental.TableDelta` streams — messages from
  unchanged subtrees are reused across tree levels, across trees, and
  across deltas (the Relational Data Borg direction: maintained
  aggregates feed retraining, not just serving).  Host-orchestrated
  (signatures hash concrete mask bytes), hence not jittable; every
  segment-⊕ emission is counted for real.

Engines also own the trainer's *data surface* (row-domain sizes, the
feature matrices masks and split plans are built from), because the
maintained path works on capacity-padded dynamic stores whose row space
is wider than the static schema's.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed import spmd
from .semiring import Arithmetic
from .sketch import sketch_factors


class QueryEngine:
    """Strategy interface for the Booster's grouped SumProd queries.

    ``bind(booster)`` is called once from ``Booster.__init__`` with the
    fully-constructed trainer (schema, semirings, sketch hashes); the
    engine builds its per-table base factors there.

    ``jittable``: grouped queries are pure jax and safe to trace (the
    trainer then jits level steps and uses ``lax.fori_loop``); host-side
    caching engines set False and the trainer runs eagerly with Python
    loops.  ``analytic_edges``: the trainer bumps ``QueryCounter.edges``
    analytically (one emission per join-tree edge per query family — jit
    caching would otherwise undercount); engines that count real
    emissions themselves set False.
    """

    jittable: bool = True
    analytic_edges: bool = True

    def bind(self, booster) -> None:
        raise NotImplementedError

    # ------------------------------------------------------------- queries --
    def grouped_c3(self, table: str, masks, extra=None):
        """(K, rows(table), 3): (count, Σy, Σy²) grouped by ``table``,
        batched over the K node-mask rows; ``extra`` conjoins optional
        per-table masks (a previous tree's leaf)."""
        raise NotImplementedError

    def grouped_count_pair(self, table: str, masks, extra_a, extra_b):
        """(K, rows(table)): |J^{(a)} ∩ J^{(b)} ∩ J^{(v)} ∩ ρ⋈·| counts."""
        raise NotImplementedError

    def grouped_sketch(self, table: str, masks, extra=None, labeled=False):
        """(K, rows(table), k_c): polynomial-semiring sketch grouped by
        ``table``; ``labeled`` weights the label table's factor by y."""
        raise NotImplementedError

    # -------------------------------------------------------- data surface --
    def n_rows(self, table: str) -> int:
        """Row-id domain of ``table`` (schema rows, or store capacity)."""
        raise NotImplementedError

    def mask_featmat(self, table: str) -> Optional[jnp.ndarray]:
        """Feature matrix for mask descent; None → the schema's static
        device-resident matrix."""
        raise NotImplementedError

    def plan_featmats(self) -> Optional[Dict[str, jnp.ndarray]]:
        """Per-table feature matrices for split plans (dead rows pushed
        to +inf so they never become thresholds); None → schema static."""
        raise NotImplementedError

    def plan_featmat(self, table: str):
        """The single-table complement of ``plan_featmats``: one
        capacity-shaped (n_rows(table), d_t) float32 matrix, dead slots
        at +inf.  Hist-plan edge re-quantization uses this so one
        drifted table never materializes the whole store."""
        raise NotImplementedError

    def plan_delta(self):
        """Per-table feature-row changes since the last call, consumed
        on read: ``{table: (slots, vals)}`` with ``vals`` of shape
        (len(slots), d_t) float32 and dead slots at +inf — the
        O(|delta|) input to incremental hist-plan maintenance
        (``Booster.refresh_plans``).  ``None`` means the engine does not
        track deltas and the caller must rebuild plans wholesale; an
        empty dict means nothing changed."""
        return None


class DirectEngine(QueryEngine):
    """The paper's execution model: a full vmapped SumProd pass per query
    family over the static schema (previously inlined in ``Booster``).

    Data-parallel under a mesh: the engine captures the ambient
    `spmd` data mesh at ``bind`` time.  Because the per-table base
    factors are jit *closure constants* (the level step closes over the
    engine), device placement would not survive tracing — so sharding is
    expressed in-graph instead: each masked factor is constrained to
    row shards inside the vmapped query, and the grouped output is
    constrained replicated at the engine boundary.  GSPMD then runs the
    heavy mask/⊗/segment-⊕ work sharded while the split sweep downstream
    sees replicated stats — identical control flow to single-device.
    """

    jittable = True
    analytic_edges = True

    def bind(self, booster) -> None:
        schema = booster.schema
        self.schema = schema
        self.mesh = spmd.current_data_mesh()
        self.sp = booster.sp
        self.c3 = booster.c3
        self.sem = booster.sem
        lbl = schema.labels
        self._c3_base = {}
        for t in schema.tables:
            if t.name == schema.label_table:
                self._c3_base[t.name] = jnp.stack(
                    [jnp.ones_like(lbl), lbl, jnp.square(lbl)], axis=-1
                )
            else:
                self._c3_base[t.name] = self.c3.ones((t.n_rows,))
        # unweighted monomial factors (weights applied per query by linearity)
        self._sk_base = sketch_factors(
            schema, self.sem, booster.hashes, schema.label_table,
            jnp.ones_like(lbl),
        )
        self._sk_label = dict(self._sk_base)
        self._sk_label[schema.label_table] = self.sem.scale(
            self._sk_base[schema.label_table], lbl
        )

    # ------------------------------------------------------------- queries --
    def grouped_c3(self, table, masks, extra=None):
        def one(mrow):
            f = {}
            for tn in mrow:
                keep = mrow[tn] if extra is None else (mrow[tn] & extra[tn])
                f[tn] = spmd.constrain_rows(
                    self.c3.mask(self._c3_base[tn], keep), self.mesh)
            return self.sp(self.c3, f, group_by=table)

        with spmd.use_data_mesh(self.mesh):
            return spmd.replicate(jax.vmap(one)(masks), self.mesh)

    def grouped_count_pair(self, table, masks, extra_a, extra_b):
        ar = Arithmetic()

        def one(mrow):
            f = {
                tn: spmd.constrain_rows(ar.mask(
                    jnp.ones((self.schema.table(tn).n_rows,), jnp.float32),
                    mrow[tn] & extra_a[tn] & extra_b[tn],
                ), self.mesh)
                for tn in mrow
            }
            return self.sp(ar, f, group_by=table)

        with spmd.use_data_mesh(self.mesh):
            return spmd.replicate(jax.vmap(one)(masks), self.mesh)

    def grouped_sketch(self, table, masks, extra=None, labeled=False):
        base = self._sk_label if labeled else self._sk_base

        def one(mrow):
            f = {}
            for tn in mrow:
                keep = mrow[tn] if extra is None else (mrow[tn] & extra[tn])
                f[tn] = spmd.constrain_rows(
                    self.sem.mask(base[tn], keep), self.mesh)
            return self.sp(self.sem, f, group_by=table)

        with spmd.use_data_mesh(self.mesh):
            return spmd.replicate(jax.vmap(one)(masks), self.mesh)

    # -------------------------------------------------------- data surface --
    def n_rows(self, table):
        return self.schema.table(table).n_rows

    def mask_featmat(self, table):
        return None

    def plan_featmats(self):
        return None

    def plan_featmat(self, table):
        return np.asarray(self.schema.featmat[table], np.float32)

    def plan_delta(self):
        return {}                  # static schema: nothing ever changes
