"""Core: the paper's contribution — relational boosted regression trees.

Public API:
    Schema, Table                    — relational data (schema.py)
    SumProd, materialize_join        — query engine (sumprod.py)
    semirings                        — Arithmetic/Channels/PolyCoeff/PolyFreq/...
    TableHashes, sketch_factors      — tensor sketch (sketch.py)
    Booster, BoostConfig             — Algorithms 1–3 (trainer.py)
    TableHistPlan, build_hist_plans  — quantile-histogram split plans (hist.py)
    MaterializedBooster              — the paper's baseline (baseline.py)
    TreeArrays, predict_rows         — trees (tree.py)
"""
from .engine import DirectEngine, QueryEngine
from .hist import TableHistPlan, build_hist_plans, quantile_cuts, refresh_hist_plans
from .schema import NotAcyclicError, Schema, Table
from .semiring import Arithmetic, BooleanSR, Channels, PolyCoeff, PolyFreq, Tropical
from .sketch import Hash2, TableHashes, count_sketch_dense, sketch_factors, tensor_sketch_dense
from .sumprod import MessageCache, QueryCounter, SumProd, materialize_join, refresh_plan
from .trainer import BoostConfig, Booster, FitTrace
from .baseline import MaterializedBooster
from .tree import TreeArrays, leaf_masks, predict_rows

__all__ = [
    "NotAcyclicError", "Schema", "Table",
    "Arithmetic", "BooleanSR", "Channels", "PolyCoeff", "PolyFreq", "Tropical",
    "Hash2", "TableHashes", "count_sketch_dense", "sketch_factors", "tensor_sketch_dense",
    "MessageCache", "QueryCounter", "SumProd", "materialize_join", "refresh_plan",
    "DirectEngine", "QueryEngine",
    "BoostConfig", "Booster", "FitTrace", "MaterializedBooster",
    "TableHistPlan", "build_hist_plans", "quantile_cuts", "refresh_hist_plans",
    "TreeArrays", "leaf_masks", "predict_rows",
]
