"""Relational schema: tables, join hypergraph, GYO acyclicity, join trees.

A dataset with d features is stored in τ tables; the design matrix
``J = T_1 ⋈ … ⋈ T_τ`` (natural join, bag semantics) is *never*
materialized outside tests.  Schema construction is host-side (numpy-ish,
static): it builds, once, everything the jitted SumProd passes need —
rooted join trees and per-edge dense join-key dictionaries.

Acyclicity is decided by the GYO ear decomposition (paper Def. A.4); the
ear-witness edges *are* the join tree.  For acyclic joins fhtw = 1
(Observation 1) and inside-out runs in O(n) semiring ops per query after
the static key dictionaries replace the paper's per-query O(n log n) sort.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp


class NotAcyclicError(ValueError):
    """Raised when the join hypergraph has no GYO ear decomposition."""


@dataclasses.dataclass
class Table:
    """A named relation.  All columns are 1-D, equal length.

    ``feature_columns``: the columns on which tree splits may be proposed
    (the paper's features; join keys may be features too).  Join keys are
    inferred by natural-join semantics: any column name appearing in more
    than one table.  Key columns must be integer-typed.
    """

    name: str
    columns: Dict[str, np.ndarray]
    feature_columns: Tuple[str, ...] = ()

    def __post_init__(self):
        lens = {len(v) for v in self.columns.values()}
        if len(lens) != 1:
            raise ValueError(f"table {self.name}: ragged columns {lens}")
        if not self.feature_columns:
            self.feature_columns = tuple(self.columns.keys())
        for c in self.feature_columns:
            if c not in self.columns:
                raise ValueError(f"table {self.name}: unknown feature column {c}")

    @property
    def n_rows(self) -> int:
        return len(next(iter(self.columns.values())))

    def col(self, name: str) -> np.ndarray:
        return np.asarray(self.columns[name])


@dataclasses.dataclass(frozen=True)
class TreeEdge:
    """Directed join-tree edge child → parent with a dense key dictionary."""

    child: int                 # table index
    parent: int                # table index
    key_cols: Tuple[str, ...]  # shared columns (the join key of this edge)
    child_ids: jnp.ndarray     # (n_child,)  dense key id per child row
    parent_ids: jnp.ndarray    # (n_parent,) dense key id per parent row
    n_keys: int                # key-domain size


@dataclasses.dataclass(frozen=True)
class JoinTree:
    """Leaf→root elimination order for one root table."""

    root: int
    edges: Tuple[TreeEdge, ...]   # in elimination (leaf-first) order


def _key_dict(ta: Table, tb: Table, cols: Sequence[str]):
    """Dense dictionary over the union of both tables' key tuples."""
    ka = np.stack([ta.col(c) for c in cols], axis=1)
    kb = np.stack([tb.col(c) for c in cols], axis=1)
    both = np.concatenate([ka, kb], axis=0)
    _, inv = np.unique(both, axis=0, return_inverse=True)
    n = int(inv.max()) + 1 if len(inv) else 0
    return (
        jnp.asarray(inv[: len(ka)], jnp.int32),
        jnp.asarray(inv[len(ka):], jnp.int32),
        n,
    )


class Schema:
    """An acyclic relational schema plus all static query-plan artifacts."""

    def __init__(self, tables: Sequence[Table], label: Tuple[str, str]):
        self.tables: List[Table] = list(tables)
        self.names = [t.name for t in self.tables]
        if len(set(self.names)) != len(self.names):
            raise ValueError("duplicate table names")
        self.index = {n: i for i, n in enumerate(self.names)}
        self.label_table, self.label_column = label
        if self.label_table not in self.index:
            raise ValueError(f"label table {self.label_table} not in schema")

        # --- feature ownership: first table containing a column owns it ---
        # (the paper's E_t assignment; used by sketching and split search)
        self.owner: Dict[str, str] = {}
        for t in self.tables:
            for c in t.columns:
                self.owner.setdefault(c, t.name)
        # global feature list: every ownable column except the label
        self.features: List[Tuple[str, str]] = []  # (table, column), owner only
        for t in self.tables:
            for c in t.feature_columns:
                if self.owner[c] == t.name and not (
                    t.name == self.label_table and c == self.label_column
                ):
                    self.features.append((t.name, c))

        # --- hypergraph + GYO -------------------------------------------------
        self._undirected_edges = self._gyo()   # list[(a, b, key_cols)] a-b adjacency
        self._tree_cache: Dict[int, JoinTree] = {}
        for n in self.names:                   # eager: jit-safe + one-time cost
            self._build_join_tree(n)

        # --- per-table device-resident feature matrices ----------------------
        self.feat_cols: Dict[str, List[str]] = {
            t.name: [c for (tn, c) in self.features if tn == t.name] for t in self.tables
        }
        self.featmat: Dict[str, jnp.ndarray] = {}
        for t in self.tables:
            cols = self.feat_cols[t.name]
            if cols:
                self.featmat[t.name] = jnp.asarray(
                    np.stack([t.col(c).astype(np.float32) for c in cols], axis=1)
                )
            else:
                self.featmat[t.name] = jnp.zeros((t.n_rows, 0), jnp.float32)
        # global feature id → (table idx, local idx)
        self.feat_global: List[Tuple[int, int]] = []
        for ti, t in enumerate(self.tables):
            for li, _ in enumerate(self.feat_cols[t.name]):
                self.feat_global.append((ti, li))
        self.n_features = len(self.feat_global)

        self.labels = jnp.asarray(
            self.tables[self.index[self.label_table]].col(self.label_column).astype(np.float32)
        )

        # --- sketch projection dictionaries (paper §3: w_t(x), |D_t|) -------
        # D_t = distinct projections of T_t onto its *owned* columns.
        self.w_ids: Dict[str, jnp.ndarray] = {}
        self.domain_sizes: Dict[str, int] = {}
        for t in self.tables:
            owned = [c for c in t.columns if self.owner[c] == t.name]
            if owned:
                proj = np.stack([t.col(c) for c in owned], axis=1)
                _, inv = np.unique(proj, axis=0, return_inverse=True)
                self.w_ids[t.name] = jnp.asarray(inv, jnp.int32)
                self.domain_sizes[t.name] = int(inv.max()) + 1
            else:
                self.w_ids[t.name] = jnp.zeros((t.n_rows,), jnp.int32)
                self.domain_sizes[t.name] = 1

    # ------------------------------------------------------------------ GYO --
    def _gyo(self):
        """GYO ear decomposition.  Returns undirected join-tree edges;
        raises NotAcyclicError if the hypergraph is cyclic."""
        cols = {t.name: set(t.columns) for t in self.tables}
        alive = set(self.names)
        edges: List[Tuple[str, str, Tuple[str, ...]]] = []
        while len(alive) > 1:
            progress = False
            for a in sorted(alive):
                others = [b for b in alive if b != a]
                # columns of a shared with any other living table
                shared = {
                    c for c in cols[a] if any(c in cols[b] for b in others)
                }
                witness = next(
                    (b for b in sorted(others) if shared <= cols[b]), None
                )
                if witness is not None:
                    edges.append((a, witness, tuple(sorted(shared))))
                    alive.remove(a)
                    progress = True
                    break
            if not progress:
                raise NotAcyclicError(
                    f"join hypergraph is cyclic (stuck with {sorted(alive)}); "
                    "fhtw > 1 is out of scope (paper handles acyclic joins)"
                )
        return edges

    # ------------------------------------------------------------- join tree --
    def join_tree(self, root: str) -> JoinTree:
        """Rooted join tree (precomputed in __init__; jit-safe lookup)."""
        return self._tree_cache[self.index[root]]

    def _build_join_tree(self, root: str) -> JoinTree:
        ri = self.index[root]
        if ri in self._tree_cache:
            return self._tree_cache[ri]
        adj: Dict[str, List[Tuple[str, Tuple[str, ...]]]] = {n: [] for n in self.names}
        for a, b, key in self._undirected_edges:
            adj[a].append((b, key))
            adj[b].append((a, key))
        # BFS from root to get parent pointers
        parent: Dict[str, Tuple[str, Tuple[str, ...]]] = {}
        seen = {root}
        frontier = [root]
        order = [root]
        while frontier:
            nxt = []
            for u in frontier:
                for v, key in adj[u]:
                    if v not in seen:
                        seen.add(v)
                        parent[v] = (u, key)
                        nxt.append(v)
                        order.append(v)
            frontier = nxt
        if len(seen) != len(self.names):
            raise ValueError("join graph is disconnected (cross join unsupported)")
        # elimination order: reverse BFS (leaves first)
        edges = []
        for v in reversed(order[1:]):
            p, key = parent[v]
            cid, pid, n = _key_dict(
                self.tables[self.index[v]], self.tables[self.index[p]], key
            )
            edges.append(
                TreeEdge(
                    child=self.index[v], parent=self.index[p], key_cols=key,
                    child_ids=cid, parent_ids=pid, n_keys=n,
                )
            )
        jt = JoinTree(root=ri, edges=tuple(edges))
        self._tree_cache[ri] = jt
        return jt

    # ----------------------------------------------------------------- misc --
    @property
    def n_tables(self) -> int:
        return len(self.tables)

    def table(self, name: str) -> Table:
        return self.tables[self.index[name]]

    def feature_name(self, gid: int) -> Tuple[str, str]:
        ti, li = self.feat_global[gid]
        t = self.tables[ti]
        return t.name, self.feat_cols[t.name][li]
