"""Quantile-histogram split plans with incrementally maintained bins.

LightGBM-style alternative to the exact sweep in splits.py: instead of
cumsumming node statistics over full per-feature argsort orders and
scoring every row boundary (O(K·d_t·n) per table per level), aggregate
the (K, rows) stats into (K, d_t, B) histograms of B quantile bins and
sweep the B cut boundaries — the O(n)-length prefix scan and per-row
score evaluation collapse to O(B) (JoinBoost, Huang et al. 2023 uses
the same structure over normalized data).

Design invariants:

- **Cuts are data values.**  Every bin boundary is an actual column
  value, so the split ``x >= cut → right`` partitions rows exactly along
  a bin boundary: binned (n, Σr) statistics score every candidate cut
  EXACTLY (no approximation inside the candidate set — only the set
  itself is quantile-subsampled).  When B ≥ #distinct values, the cut
  set equals the exact sweep's candidate set and the two routes select
  identical splits.  Thresholds come out of the same value domain, so
  mask descent (``tree.descend_masks_level``) and serving compile are
  untouched.
- **Non-finite values bin to an explicit INVALID bin** (index
  ``n_bins``): maintained engines pad dead capacity slots at +inf
  (``QueryEngine.plan_featmats``), and those slots must neither shape
  the quantile edges nor ever become thresholds.  Invalid-bin rows are
  excluded from the histogram row lists outright — they are not even
  gathered.  (Their node stats are ⊕-zero anyway — this is
  safe-by-construction on top.)
- **The maintained aggregate is the bin map, not the sort** (Kara et
  al. 2021's static/dynamic split): under table deltas only the touched
  rows re-bin against frozen edges — O(|delta|·d_t·log B) via
  :func:`rebin_rows` — and the edges themselves re-quantize only when
  cumulative re-binned mass drifts past a tolerance
  (:func:`refresh_hist_plans`).  Untouched tables are reused as-is; the
  exact route's per-epoch all-tables O(n log n · d_t) float argsort
  rebuild disappears.

Histogram accumulation routes (``TableHistPlan.route`` /
``BoostConfig.hist_route``; ``"auto"`` — the default — picks gather
unless column skew inflates the padded row lists, then scatter):

- ``"gather"``: quantile bins are count-balanced by
  construction, so each (feature, bin) keeps a padded row-id list
  ((d_t, B, m) with m ≈ n/B, rebuilt per dirty table by an O(n) integer
  radix sort); per-bin sums are one out-of-bounds-fills-zero gather +
  a short-axis reduction.  This avoids both XLA's serial scatter-add
  and the O(n)-length cumsum — the fast CPU lowering.
- ``"scatter"`` / ``"kernel"``: one fused segment-⊕ of the
  feature-major flattened bin ids through the kernels/segment_sum
  path — the pure-XLA oracle, or the Pallas one-hot-matmul kernel that
  reformulates the scatter for the MXU (the TPU-shaped lowering; on
  CPU the gather route wins).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels.segment_sum.ops import segment_sum_op
from ..kernels.segment_sum.ref import segment_sum_ref
from .schema import Schema
from .splits import score_boundaries

HIST_DEFAULT_BINS = 256


def quantile_cuts(col: np.ndarray, n_bins: int) -> np.ndarray:
    """≤ ``n_bins − 1`` cut values for one column, drawn from the
    column's own finite values at count-weighted quantile positions (a
    merged-quantile sketch over the distinct-value histogram).  A cut c
    opens the bin of values ≥ c, so candidate splits sit on real data
    and the minimum value is never a cut (its left side would be empty).
    When the column has ≤ ``n_bins`` distinct values every one gets its
    own bin and the cut set equals the exact sweep's candidates."""
    col = np.asarray(col)
    finite = col[np.isfinite(col)]
    if finite.size == 0:
        return np.zeros((0,), np.float32)
    d, counts = np.unique(finite, return_counts=True)
    if len(d) <= n_bins:
        return d[1:].astype(np.float32)
    cum = np.cumsum(counts)
    targets = cum[-1] * np.arange(1, n_bins) / n_bins
    idx = np.searchsorted(cum, targets, side="left") + 1
    idx = np.unique(np.clip(idx, 1, len(d) - 1))
    return d[idx].astype(np.float32)


def bin_values(cuts: np.ndarray, x: np.ndarray, n_bins: int) -> np.ndarray:
    """Row→bin map for one feature: ``searchsorted`` right over the real
    cuts (monotone in x), non-finite values to the invalid bin."""
    b = np.searchsorted(cuts, x, side="right").astype(np.int32)
    b[~np.isfinite(x)] = n_bins
    return b


def _padded_bin_rows(bins: np.ndarray, n_bins: int) -> np.ndarray:
    """(d_t, B, m) row-id lists per (feature, bin), padded with the
    out-of-bounds id n (the sweep gathers with fill_value 0).  m is the
    max VALID-bin occupancy (rounded up to 8 for shape stability under
    small deltas); invalid-bin rows are simply absent.  Quantile bins
    keep occupancy ≈ n/B, so the padding overhead stays small unless a
    single value owns a large fraction of the column (its bin can't be
    subdivided — candidate splits never cut through equal values)."""
    d_t, n = bins.shape
    order = np.argsort(bins, axis=1, kind="stable").astype(np.int32)
    sb = np.take_along_axis(bins, order, axis=1)
    m = 1
    per_f = []
    for f in range(d_t):
        keep = sb[f] < n_bins
        vb, rows = sb[f][keep], order[f][keep]
        start = np.searchsorted(vb, np.arange(n_bins), side="left")
        rank = np.arange(len(vb)) - start[vb]
        per_f.append((vb, rank, rows))
        if len(vb):
            m = max(m, int(rank.max()) + 1)
    m = ((m + 7) // 8) * 8
    out = np.full((d_t, n_bins, m), n, np.int32)
    for f, (vb, rank, rows) in enumerate(per_f):
        out[f, vb, rank] = rows
    return out


@dataclasses.dataclass
class TableHistPlan:
    """Maintainable per-table histogram artifacts.

    Host-side masters (numpy) are the mutable source of truth —
    :func:`rebin_rows` updates them in place in O(|delta|) — and the
    device view used by the sweep (padded row lists + cut values)
    refreshes eagerly on every mutation via :meth:`device`.

    Bin index layout per feature f: valid values take bins
    ``0 … n_cuts[f]`` (boundary j splits bins ≤ j from bins > j at
    threshold ``cuts[f, j]``); non-finite values take the invalid bin
    ``n_bins``, beyond every candidate boundary.
    """

    table: str
    n_bins: int                 # B: valid bins 0..B-1, invalid bin = B
    cuts: np.ndarray            # (d_t, B-1) f32 cut values, +inf padded
    n_cuts: np.ndarray          # (d_t,) int32 real cuts per feature
    bins: np.ndarray            # (d_t, n) int32 row→bin master
    global_ids: jnp.ndarray     # (d_t,) global feature ids
    route: str = "auto"         # histogram accumulation (hist_scores)
    rebinned_since_edges: int = 0   # drift meter for edge re-quantization
    _dev: Optional[Tuple] = None
    _rows: Optional[jnp.ndarray] = None

    @property
    def n_rows(self) -> int:
        return self.bins.shape[1]

    def device(self):
        """(route, bin_rows, bins, cuts, valid_cut): the resolved
        accumulation route and its device arrays.  ``"auto"`` resolves
        here — gather while the padded row lists stay within 4× the row
        count, else the segment-⊕ scatter (a value hoarding a large
        fraction of a column inflates the max bin occupancy m, and a
        (…, B, m) row-list tensor must not be built, let alone gathered,
        on skew the quantile edges can't balance away).  ``bin_rows`` is
        None unless the route is gather.  Kept fresh eagerly by the
        constructors/mutators — under a jitted trace the cached view
        must already exist (materializing it there would capture
        trace-scoped constants)."""
        if self._dev is None:
            valid = (np.arange(self.n_bins - 1)[None, :]
                     < self.n_cuts[:, None])
            route = self.route
            if route == "auto":
                m = max(
                    (int(np.bincount(
                        f, minlength=self.n_bins)[: self.n_bins].max())
                     for f in self.bins if f.size), default=1)
                padded = self.n_bins * (((max(m, 1) + 7) // 8) * 8)
                route = ("gather" if padded <= 4 * max(self.n_rows, 1)
                         else "scatter")
            self._dev = (
                route,
                self.gather_rows() if route == "gather" else None,
                jnp.asarray(self.bins),
                jnp.asarray(self.cuts),
                jnp.asarray(valid),
            )
        return self._dev

    def gather_rows(self) -> jnp.ndarray:
        """Padded per-(feature, bin) row lists for the gather route,
        built on first use (eager contexts only — the resolved device
        view prebuilds it when the route is gather)."""
        if self._rows is None:
            self._rows = jnp.asarray(
                _padded_bin_rows(self.bins, self.n_bins))
        return self._rows


def _table_plan(name: str, fm: np.ndarray, global_ids, n_bins: int,
                route: str = "auto") -> TableHistPlan:
    d_t, n = fm.shape[1], fm.shape[0]
    cuts = np.full((d_t, n_bins - 1), np.inf, np.float32)
    n_cuts = np.zeros((d_t,), np.int32)
    bins = np.empty((d_t, n), np.int32)
    for f in range(d_t):
        c = quantile_cuts(fm[:, f], n_bins)
        n_cuts[f] = len(c)
        cuts[f, : len(c)] = c
        bins[f] = bin_values(c, fm[:, f], n_bins)
    plan = TableHistPlan(
        table=name, n_bins=n_bins, cuts=cuts, n_cuts=n_cuts, bins=bins,
        global_ids=jnp.asarray(np.asarray(global_ids, np.int32)),
        route=route,
    )
    plan.device()
    return plan


def build_hist_plans(
    schema: Schema,
    featmats: Optional[Dict[str, np.ndarray]] = None,
    n_bins: int = HIST_DEFAULT_BINS,
    route: str = "auto",
) -> Dict[str, TableHistPlan]:
    """Full (re)build, mirroring ``splits.build_split_plans``:
    ``featmats`` overrides the schema's static matrices — maintained
    engines pass capacity-shaped matrices whose dead slots sit at +inf,
    which here bin to the invalid slot and are excluded from the
    quantile edges."""
    plans = {}
    for t in schema.tables:
        src = (featmats[t.name] if featmats is not None and t.name in featmats
               else schema.featmat[t.name])
        fm = np.asarray(src, np.float32)
        if fm.shape[1] == 0:
            continue
        gids = [
            g for g, (ti, _li) in enumerate(schema.feat_global)
            if schema.tables[ti].name == t.name
        ]
        plans[t.name] = _table_plan(t.name, fm, gids, n_bins, route=route)
    return plans


def rebin_rows(
    plan: TableHistPlan,
    rows: np.ndarray,
    vals: np.ndarray,
    n_rows: Optional[int] = None,
) -> None:
    """Re-bin ``rows`` (slot ids) whose feature values became ``vals``
    ((len(rows), d_t), dead rows at +inf) against the plan's FROZEN
    edges, in place — the bin-map update is O(|rows|·d_t·log B),
    independent of table size; only the padded row lists of THIS table
    re-pack (an O(n) integer radix sort — no float comparison sort, and
    untouched tables pay nothing).  ``n_rows`` extends the row domain
    (capacity growth); new slots start in the invalid bin, exactly
    where +inf dead padding belongs."""
    rows = np.asarray(rows, np.int64)
    d_t = plan.bins.shape[0]
    need = max(plan.n_rows, int(rows.max()) + 1 if len(rows) else 0,
               int(n_rows or 0))
    if need > plan.n_rows:
        pad = np.full((d_t, need - plan.n_rows), plan.n_bins, np.int32)
        plan.bins = np.concatenate([plan.bins, pad], axis=1)
    if len(rows):
        vals = np.asarray(vals, np.float32)
        for f in range(d_t):
            plan.bins[f, rows] = bin_values(
                plan.cuts[f, : plan.n_cuts[f]], vals[:, f], plan.n_bins
            )
        plan.rebinned_since_edges += len(rows)
    plan._dev = None
    plan._rows = None
    plan.device()


def refresh_hist_plans(
    plans: Dict[str, TableHistPlan],
    dirty: Dict[str, Tuple[np.ndarray, np.ndarray]],
    n_rows_fn: Callable[[str], int],
    featmat_fn: Callable[[str], np.ndarray],
    n_bins: int = HIST_DEFAULT_BINS,
    edge_tol: float = 0.25,
) -> Dict[str, TableHistPlan]:
    """Delta-driven plan maintenance: tables absent from ``dirty``
    (``{table: (rows, vals)}``) are reused untouched; dirty tables
    re-bin only the given rows against frozen edges, unless the
    cumulative re-binned mass since the edges were built exceeds
    ``edge_tol`` of the row domain — quantile drift — in which case that
    table's edges re-quantize from its full feature matrix
    (``edge_tol = 0`` re-quantizes on any change, pinning exact parity
    with a fresh build; ``featmat_fn(table)`` materializes only the
    drifted table, never the whole store)."""
    out = dict(plans)
    for name, (rows, vals) in dirty.items():
        plan = plans.get(name)
        if plan is None:                       # feature-less table
            continue
        cap = int(n_rows_fn(name))
        if plan.rebinned_since_edges + len(rows) > edge_tol * max(cap, 1):
            out[name] = _table_plan(
                name, np.asarray(featmat_fn(name), np.float32),
                plan.global_ids, n_bins, route=plan.route,
            )
        elif len(rows) or cap > plan.n_rows:
            rebin_rows(plan, rows, vals, n_rows=cap)
    return out


def hist_scores(plan: TableHistPlan, n: jnp.ndarray, s: jnp.ndarray,
                tot_n: jnp.ndarray, tot_s: jnp.ndarray,
                route: Optional[str] = None):
    """Histogram sweep for one table: accumulate the (K, rows) node
    stats into (2K, d_t, B) histograms (via the padded-row-list gather
    or a fused segment-⊕ — see the module docstring), then score every
    cut boundary from B-bin cumsums.  ``route`` overrides the plan's
    resolved route (an eager/test affordance — forcing "gather" on a
    scatter-resolved plan builds the row lists on demand).  Returns
    per-(node, feature) best-boundary arrays (score, thr, sl, nl, sr,
    nr), each (K, d_t) — the same contract as the exact sweep, consumed
    by ``splits._best_feature``."""
    dev_route, bin_rows, bins, cuts, valid_cut = plan.device()
    d_t = bins.shape[0]
    K = n.shape[0]
    B = plan.n_bins
    if route is None or route == "auto":
        route = dev_route
    stats = jnp.concatenate([n, s], axis=0)              # (2K, rows)
    if route == "gather":
        if bin_rows is None:
            bin_rows = plan.gather_rows()
        g = jnp.take(stats, bin_rows, axis=1, mode="fill", fill_value=0.0)
        hist = jnp.sum(g, axis=3)                        # (2K, d_t, B)
    elif route in ("scatter", "kernel"):
        seg = segment_sum_op if route == "kernel" else segment_sum_ref
        nb = B + 1                                       # + the invalid slot
        ids = (bins + (jnp.arange(d_t, dtype=jnp.int32) * nb)[:, None])
        h = seg(jnp.tile(stats.T, (d_t, 1)), ids.reshape(-1), d_t * nb)
        hist = h.reshape(d_t, nb, 2 * K)[:, :B].transpose(2, 0, 1)
    else:
        raise ValueError(f"hist route {route!r}")
    # boundary j (threshold cuts[f, j]) sends bins ≤ j left, > j right;
    # invalid-bin rows sit past every boundary (and carry ⊕-zero stats)
    cum = jnp.cumsum(hist, axis=2)[..., : B - 1]         # (2K, d_t, B-1)
    nl, sl = cum[:K], cum[K:]
    nr = tot_n[:, None, None] - nl
    sr = tot_s[:, None, None] - sl
    valid = valid_cut[None] & (nl > 0) & (nr > 0)
    return score_boundaries(nl, sl, nr, sr, valid, cuts[None])
