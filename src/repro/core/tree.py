"""Array-encoded regression trees (SoA pytrees) + relational masks.

A tree of depth D is complete-binary in heap layout: internal node k at
level ℓ has within-level index k ∈ [0, 2^ℓ); its children are 2k (left)
and 2k+1 (right).  Splits are the paper's ``J_feat ≥ thr → right``.
Dead nodes (no valid split / empty) carry thr = +inf so every point
routes left; the left descendant leaf holds the node's mean.

The relational core never materializes J; node/leaf membership lives as
*per-table row masks*: a row r of table T_t passes node v iff it
satisfies every constraint on the root→v path whose feature is owned by
T_t (constraints on other tables' features don't constrain T_t's rows —
the ⊗ of factors conjoins them across tables inside the SumProd query).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp

from .schema import Schema


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TreeArrays:
    """One regression tree.  Leaves: L = 2^depth.

    feat:  (L-1,) int32   global feature id per internal node (-1 = dead)
    thr:   (L-1,) float32 threshold (+inf on dead nodes → route left)
    leaf:  (L,)   float32 leaf predictions
    """

    feat: jnp.ndarray
    thr: jnp.ndarray
    leaf: jnp.ndarray

    @property
    def depth(self) -> int:
        return int(self.leaf.shape[0]).bit_length() - 1

    @staticmethod
    def empty(depth: int) -> "TreeArrays":
        L = 2 ** depth
        return TreeArrays(
            feat=jnp.full((L - 1,), -1, jnp.int32),
            thr=jnp.full((L - 1,), jnp.inf, jnp.float32),
            leaf=jnp.zeros((L,), jnp.float32),
        )

    def level_slice(self, level: int):
        """Within-level views of feat/thr for nodes at ``level``."""
        start = 2 ** level - 1
        size = 2 ** level
        return (
            jax.lax.dynamic_slice_in_dim(self.feat, start, size),
            jax.lax.dynamic_slice_in_dim(self.thr, start, size),
        )


def predict_rows(trees: List[TreeArrays], X: jnp.ndarray, lr: float = 1.0) -> jnp.ndarray:
    """Boosted prediction on a materialized feature matrix (tests/baseline).

    X: (n, d_global) in *global feature id* order.
    """
    out = jnp.zeros((X.shape[0],), jnp.float32)
    for t in trees:
        idx = jnp.zeros((X.shape[0],), jnp.int32)  # within-level index
        for level in range(t.depth):
            feat, thr = t.level_slice(level)
            f = jnp.take(feat, idx)
            th = jnp.take(thr, idx)
            v = jnp.take_along_axis(X, jnp.maximum(f, 0)[:, None], axis=1)[:, 0]
            go_right = (v >= th) & (f >= 0)
            idx = 2 * idx + go_right.astype(jnp.int32)
        out = out + lr * jnp.take(t.leaf, idx)
    return out


# ---------------------------------------------------------------------------
# Relational masks
# ---------------------------------------------------------------------------

def _local_feature_view(schema: Schema, table: str, featmat=None):
    """(g2l, featmat): map global feature id → local column, -1 if foreign.

    ``featmat`` overrides the schema's device-resident (n_rows, d_t)
    matrix — used by incremental maintenance to evaluate masks for just a
    delta's rows (same columns, arbitrary row subset)."""
    g2l = -jnp.ones((max(schema.n_features, 1),), jnp.int32)
    for g, (ti, li) in enumerate(schema.feat_global):
        if schema.tables[ti].name == table:
            g2l = g2l.at[g].set(li)
    return g2l, schema.featmat[table] if featmat is None else featmat


def descend_masks_level(
    schema: Schema, table: str, feat: jnp.ndarray, thr: jnp.ndarray, masks: jnp.ndarray,
    featmat=None,
) -> jnp.ndarray:
    """One level of mask refinement for ``table``.

    feat/thr: (K,) this level's chosen splits; masks: (K, n_rows) →
    (2K, n_rows).  Constraints on foreign features pass both children
    through; dead nodes (feat = -1, thr = +inf) route everything left.
    """
    g2l, fm = _local_feature_view(schema, table, featmat)
    local = jnp.take(g2l, jnp.maximum(feat, 0)) * jnp.where(feat >= 0, 1, 0) + jnp.where(
        feat >= 0, 0, -1
    )
    mine = local >= 0
    vals = jnp.take(fm, jnp.maximum(local, 0), axis=1).T        # (K, n)
    cond = vals >= thr[:, None]                                  # (K, n)
    left = masks & (~mine[:, None] | ~cond)
    right = masks & (~mine[:, None] | cond)
    return jnp.stack([left, right], axis=1).reshape(-1, masks.shape[-1])


def root_masks(schema: Schema, table: str, n_rows: int = None) -> jnp.ndarray:
    n = schema.table(table).n_rows if n_rows is None else n_rows
    return jnp.ones((1, n), jnp.bool_)


def leaf_masks(schema: Schema, table: str, tree: TreeArrays, featmat=None) -> jnp.ndarray:
    """(L, n_rows) bool: per-table projection of every leaf's J^{(ℓ)}.

    With ``featmat`` (k, d_t), masks are evaluated for those k feature
    rows instead of the whole stored table (the per-row ops are identical,
    so subset rows match the full-table pass bit-for-bit)."""
    m = root_masks(schema, table,
                   None if featmat is None else int(featmat.shape[0]))
    for level in range(tree.depth):
        feat, thr = tree.level_slice(level)
        m = descend_masks_level(schema, table, feat, thr, m, featmat)
    return m


def all_tables_leaf_masks(schema: Schema, tree: TreeArrays) -> Dict[str, jnp.ndarray]:
    return {t.name: leaf_masks(schema, t.name, tree) for t in schema.tables}
