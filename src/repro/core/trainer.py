"""Relational greedy boosted regression trees (paper Algorithms 1–3).

Faithful structure:
- Trees grow level-by-level in BFS order (paper §2.1); each level's split
  statistics come from SumProd queries *grouped by* every table T_i,
  vmapped over the level's nodes (TPU adaptation of the paper's
  query-per-node loop).
- Node statistics (n, Σy, Σy²) fuse into one Channels(3) query.
- Boosted residuals (paper §2.2):
    Σ r_x       — exact, O(mL) count queries per (node, table),
    Σ r_x²      — EXACT mode: O(m²L²) pair queries per (node, table)
                  (the paper's bottleneck, Thm 2.4),
                  SKETCH mode: O(mL) polynomial-semiring queries
                  (paper §3, Thm 3.1) with ‖·‖² via Parseval.
- Split ranking uses the paper's final MSE form; after dropping
  node-constant terms the ranking reduces to argmax(S_L²/n_L + S_R²/n_R)
  over *exact* sums — so exact and sketched training provably select
  identical splits, matching (strengthening) the paper's "similar model
  parameters" claim.  The SSR values (what the sketch accelerates) are the
  per-node losses used for reporting/stopping; tests validate their
  (1±ε) accuracy per grouping table (Thm 3.4).

Paper errata implemented correctly (see DESIGN.md §3):
- Eq.(2) label-cross term uses per-leaf label sums (the text's
  "product of sums" shortcut is not an identity);
- the final MSE line is the weighted (SSE/n_v) form.

Performance: each tree level is one jitted program (masks in, split
decision out); shapes are keyed by (level, #prev-leaves) so compiled
steps are reused across trees and runs.  SumProd query counts are
accounted *analytically* (the jit caches would otherwise undercount).

Query execution is delegated to an injectable :class:`QueryEngine`
(engine.py): the default :class:`DirectEngine` runs one vmapped SumProd
pass per query family (the paper's model, jitted); the maintained
engine (incremental/retrain.py) answers the same queries from cached
per-edge messages kept fresh under table deltas, running the level loop
eagerly so message signatures can hash concrete masks.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..obs import fence, get_registry, span
from .engine import DirectEngine, QueryEngine
from .hist import build_hist_plans, refresh_hist_plans
from .schema import Schema
from .semiring import Channels, PolyCoeff, PolyFreq
from .sketch import TableHashes
from .splits import SplitResult, best_split_for_table, build_split_plans, merge_table_results
from .sumprod import QueryCounter, SumProd
from .tree import TreeArrays, descend_masks_level, leaf_masks, root_masks


@dataclasses.dataclass(frozen=True)
class BoostConfig:
    n_trees: int = 5
    depth: int = 3
    lr: float = 1.0                  # shrinkage (paper: 1.0)
    mode: str = "exact"              # "exact" (Alg 2) | "sketch" (Alg 3)
    sketch_k: int = 64               # k = O((2+3^τ)/(ε²δ)), power of two
    sketch_domain: str = "freq"      # "freq" (beyond-paper) | "coeff" (faithful FFT)
    min_gain: float = 1e-7
    ssr_mode: str = "per_table"      # "per_table" (faithful) | "once" | "off"
    split_mode: str = "exact"        # "exact" (paper) | "hist" (quantile bins)
    hist_bins: int = 256             # B: quantile bins per feature (hist mode)
    hist_edge_tol: float = 0.25      # re-quantize a table's bin edges once this
    #                                  fraction of its rows re-binned (0 = always)
    hist_route: str = "auto"         # histogram accumulation: "auto" |
    #                                  "gather" | "scatter" | "kernel" (Pallas)
    seed: int = 0


@dataclasses.dataclass
class FitTrace:
    """Everything tests/benchmarks need to validate the paper's claims."""

    queries: int = 0
    node_ssr: List[Dict[str, jnp.ndarray]] = dataclasses.field(default_factory=list)
    node_counts: List[jnp.ndarray] = dataclasses.field(default_factory=list)


class Booster:
    """Trains boosted regression trees directly on a relational schema."""

    def __init__(self, schema: Schema, cfg: BoostConfig,
                 key: Optional[jax.Array] = None,
                 engine: Optional[QueryEngine] = None):
        self.schema = schema
        self.cfg = cfg
        self.counter = QueryCounter()
        self.sp = SumProd(schema)            # counting done analytically below
        key = key if key is not None else jax.random.PRNGKey(cfg.seed)
        self.hashes = TableHashes.make(key, schema, cfg.sketch_k)
        self.sem = (
            PolyFreq(cfg.sketch_k) if cfg.sketch_domain == "freq" else PolyCoeff(cfg.sketch_k)
        )
        self.c3 = Channels(3)
        if cfg.split_mode not in ("exact", "hist"):
            raise ValueError(f"split_mode {cfg.split_mode!r}")
        if cfg.hist_route not in ("auto", "gather", "scatter", "kernel"):
            raise ValueError(f"hist_route {cfg.hist_route!r}")
        self.engine = engine if engine is not None else DirectEngine()
        self.engine.bind(self)
        self.plans = self._build_plans()
        if self.engine.jittable:
            self._level_step = jax.jit(self._level_step_impl)
            self._leaf_masks = jax.jit(self._leaf_masks_impl)
        else:                                # host-side caching engines hash
            self._level_step = self._level_step_impl   # concrete mask bytes
            self._leaf_masks = self._leaf_masks_impl

    def _build_plans(self):
        featmats = self.engine.plan_featmats()
        if self.cfg.split_mode == "hist":
            return build_hist_plans(self.schema, featmats=featmats,
                                    n_bins=self.cfg.hist_bins,
                                    route=self.cfg.hist_route)
        return build_split_plans(self.schema, featmats=featmats)

    def refresh_plans(self):
        """Refresh split plans against the engine's current feature
        matrices (maintained engines call this per delta-epoch).  Exact
        mode rebuilds every table's argsort order wholesale; hist mode
        consumes the engine's ``plan_delta`` and re-bins only
        delta-touched rows against frozen quantile edges (re-quantizing
        a table's edges only past ``cfg.hist_edge_tol`` drift) —
        O(|delta|) plan maintenance instead of O(n log n)."""
        t0 = time.perf_counter()
        dirty = self.engine.plan_delta()   # always consumed: a full rebuild
        #                                    below covers anything accumulated
        if self.cfg.split_mode == "hist" and dirty is not None:
            with span("plan.refresh", mode="hist",
                      tables=len(dirty), rows=sum(len(s) for s, _ in dirty.values())):
                self.plans = refresh_hist_plans(
                    self.plans, dirty,
                    n_rows_fn=self.engine.n_rows,
                    featmat_fn=self.engine.plan_featmat,
                    n_bins=self.cfg.hist_bins,
                    edge_tol=self.cfg.hist_edge_tol,
                )
        else:
            with span("plan.refresh", mode=self.cfg.split_mode, full_rebuild=True):
                self.plans = self._build_plans()
        get_registry().histogram("train.plan_refresh_ms").observe(
            (time.perf_counter() - t0) * 1e3)

    # ------------------------------------------------------------- queries --
    def _grouped_c3(self, table, masks, extra=None):
        """(K, n_t, 3): (count, Σy, Σy²) grouped by `table`, batched over
        nodes.  `extra`: optional conjunctive per-table masks (prev-tree
        leaf).  Delegates to the injected engine."""
        return self.engine.grouped_c3(table, masks, extra)

    def _grouped_count_pair(self, table, masks, extra_a, extra_b):
        return self.engine.grouped_count_pair(table, masks, extra_a, extra_b)

    def _grouped_sketch(self, table, masks, extra=None, labeled=False):
        return self.engine.grouped_sketch(table, masks, extra, labeled)

    def _loop(self, n, body, init):
        """fori_loop under jit; a plain Python loop for eager engines
        (lax.fori_loop would trace the body, defeating host-side mask
        hashing and concrete indexing)."""
        if self.engine.jittable:
            return jax.lax.fori_loop(0, n, body, init)
        acc = init
        for i in range(n):
            acc = body(i, acc)
        return acc

    # ------------------------------------------------------ residual stats --
    def _table_stats(self, table, masks, prev_masks, prev_vals, want_ssr: bool):
        """(n, sum_r, node_ssr) per (node, row-of-table) at one tree level."""
        base = self._grouped_c3(table, masks)          # (K, n_t, 3)
        n, sy, uy = base[..., 0], base[..., 1], base[..., 2]
        M = prev_vals.shape[0]
        if M == 0:
            return n, sy, (jnp.sum(uy, axis=1) if want_ssr else None)

        def leaf_body(a, acc):
            sum_r, cross = acc
            extra = {tn: prev_masks[tn][a] for tn in prev_masks}
            st = self._grouped_c3(table, masks, extra=extra)
            d = prev_vals[a]
            return (sum_r - d * st[..., 0], cross + d * st[..., 1])

        sum_r, cross = self._loop(M, leaf_body, (sy, jnp.zeros_like(sy)))
        if not want_ssr:
            return n, sum_r, None

        if self.cfg.mode == "exact":
            # pair term Σ_{a,b} d_a d_b |J^{(a)} ∩ J^{(b)} ∩ J^{(v)} ∩ ρ⋈·|
            def pair_body(i, acc):
                a, b = i // M, i % M
                ea = {tn: prev_masks[tn][a] for tn in prev_masks}
                eb = {tn: prev_masks[tn][b] for tn in prev_masks}
                cnt = self._grouped_count_pair(table, masks, ea, eb)
                return acc + prev_vals[a] * prev_vals[b] * cnt

            pair = self._loop(M * M, pair_body, jnp.zeros_like(sy))
            ssr_rho = uy - 2.0 * cross + pair
        elif self.cfg.mode == "sketch":
            resid = self._grouped_sketch(table, masks, labeled=True)  # (K,n_t,kc)

            def sk_body(a, acc):
                extra = {tn: prev_masks[tn][a] for tn in prev_masks}
                s = self._grouped_sketch(table, masks, extra=extra)
                return acc - self.sem.scale(s, jnp.zeros(()) + prev_vals[a])

            resid = self._loop(M, sk_body, resid)
            ssr_rho = self.sem.norm_sq(resid)
        else:
            raise ValueError(self.cfg.mode)
        return n, sum_r, jnp.sum(ssr_rho, axis=1)

    # --------------------------------------------------------- level step --
    def _level_step_impl(self, masks, prev_masks, prev_vals, node_mean):
        """One BFS level: queries → split choice → mask descent.  Jitted;
        shape signature (K, M) keys the compile cache."""
        cfg = self.cfg
        results, ssr_out = [], {}
        node_n = None
        for i, tn in enumerate(self.plans):
            want_ssr = cfg.ssr_mode == "per_table" or (cfg.ssr_mode == "once" and i == 0)
            with span("boost.stats", table=tn):
                n, s, ssr = self._table_stats(tn, masks, prev_masks, prev_vals, want_ssr)
            if i == 0:
                node_n = jnp.sum(n, axis=1)
            if ssr is not None:
                ssr_out[tn] = ssr
            with span("boost.sweep", table=tn, mode=cfg.split_mode):
                results.append(fence(best_split_for_table(self.plans[tn], n, s)))
        best: SplitResult = merge_table_results(results)

        valid = jnp.isfinite(best.score) & (best.score > cfg.min_gain)
        feat = jnp.where(valid, best.feature, -1).astype(jnp.int32)
        thr = jnp.where(valid, best.threshold, jnp.inf).astype(jnp.float32)
        lm = jnp.where(valid, best.left_sum / jnp.maximum(best.left_cnt, 1e-9), node_mean)
        rm = jnp.where(valid, best.right_sum / jnp.maximum(best.right_cnt, 1e-9), node_mean)
        new_mean = jnp.stack([lm, rm], axis=1).reshape(-1)
        new_masks = {
            tn: descend_masks_level(self.schema, tn, feat, thr, masks[tn],
                                    featmat=self.engine.mask_featmat(tn))
            for tn in masks
        }
        return feat, thr, new_mean, new_masks, ssr_out, node_n

    def _leaf_masks_impl(self, tree: TreeArrays):
        return {
            t.name: leaf_masks(self.schema, t.name, tree,
                               featmat=self.engine.mask_featmat(t.name))
            for t in self.schema.tables
        }

    # -------------------------------------------------- query accounting --
    def _count_level_queries(self, M: int) -> int:
        """Analytic SumProd counts per level (validates Thms 2.4/3.1)."""
        tau = len(self.plans)
        per_table = 1 + M                                  # c3 + per-leaf stats
        if self.cfg.ssr_mode != "off":
            if self.cfg.mode == "exact":
                per_table += M * M                         # leaf-pair counts
            else:
                per_table += 1 + M                         # Y' + per-leaf sketches
        return per_table * tau

    def _count_level_edges(self, M: int) -> int:
        """Analytic segment-⊕ emissions per level for the direct engine:
        every query family re-emits each join-tree edge (τ_all − 1 for an
        acyclic schema, any root) — the per-query baseline the maintained
        engine's real emission counts are benchmarked against."""
        return self._count_level_queries(M) * max(self.schema.n_tables - 1, 0)

    # -------------------------------------------------------------- fitting --
    def _fit_tree(self, prev_trees: List[TreeArrays], trace: FitTrace) -> TreeArrays:
        cfg, schema = self.cfg, self.schema
        if prev_trees:
            per_tree = [self._leaf_masks(pt) for pt in prev_trees]
            prev_masks = {
                t.name: jnp.concatenate([pm[t.name] for pm in per_tree])
                for t in schema.tables
            }
            prev_vals = jnp.concatenate([pt.leaf for pt in prev_trees])
        else:
            prev_masks = {
                t.name: jnp.zeros((0, self.engine.n_rows(t.name)), jnp.bool_)
                for t in schema.tables
            }
            prev_vals = jnp.zeros((0,), jnp.float32)

        tree = TreeArrays.empty(cfg.depth)
        masks = {
            t.name: root_masks(schema, t.name, n_rows=self.engine.n_rows(t.name))
            for t in schema.tables
        }
        node_mean = jnp.zeros((1,), jnp.float32)
        M = int(prev_vals.shape[0])

        for level in range(cfg.depth):
            t0 = time.perf_counter()
            with span("boost.level", level=level, prev_leaves=M):
                feat, thr, node_mean, masks, ssr, node_n = self._level_step(
                    masks, prev_masks, prev_vals, node_mean
                )
                fence((feat, thr, node_mean))
            get_registry().histogram("train.level_ms").observe(
                (time.perf_counter() - t0) * 1e3)
            start = 2 ** level - 1
            tree = TreeArrays(
                feat=jax.lax.dynamic_update_slice_in_dim(tree.feat, feat, start, 0),
                thr=jax.lax.dynamic_update_slice_in_dim(tree.thr, thr, start, 0),
                leaf=tree.leaf,
            )
            self.counter.bump(self._count_level_queries(M))
            if self.engine.analytic_edges:
                self.counter.bump_edges(self._count_level_edges(M))
            if ssr:
                trace.node_ssr.append(ssr)
                trace.node_counts.append(node_n)

        return TreeArrays(feat=tree.feat, thr=tree.thr, leaf=cfg.lr * node_mean)

    def boost(
        self,
        trees: List[TreeArrays],
        n_trees: int,
        trace: Optional[FitTrace] = None,
    ) -> Tuple[List[TreeArrays], FitTrace]:
        """Warm start: append ``n_trees`` new trees fitted on the residuals
        of ``trees`` (which are left untouched).  ``fit()`` is
        ``boost([], cfg.n_trees)``; incremental retraining boosts on top
        of a frozen prefix after applying table deltas.  The returned
        trace reports THIS call's query cost (the lifetime total lives
        on ``self.counter``)."""
        trace = trace if trace is not None else FitTrace()
        reg = get_registry()
        q0 = self.counter.count
        trees = list(trees)
        for _ in range(n_trees):
            t0 = time.perf_counter()
            rq, re = self.counter.count, self.counter.edges
            with span("boost.round", round=len(trees),
                      mode=self.cfg.mode, split_mode=self.cfg.split_mode):
                trees.append(self._fit_tree(trees, trace))
            # per-round training telemetry: wall time, query volume, and
            # segment-⊕ emissions (real or analytic per the engine)
            reg.histogram("train.round_ms").observe(
                (time.perf_counter() - t0) * 1e3)
            reg.histogram("train.round_queries").observe(
                self.counter.count - rq)
            reg.histogram("train.round_edges").observe(
                self.counter.edges - re)
            reg.counter("train.rounds").inc()
        trace.queries = self.counter.count - q0
        return trees, trace

    def fit(self) -> Tuple[List[TreeArrays], FitTrace]:
        return self.boost([], self.cfg.n_trees)

    # ------------------------------------------------------------ serving --
    def predict_grouped(self, trees: List[TreeArrays], group_by: str):
        """Per-row-of-`group_by` (Σ ŷ(x), count) over x ∈ ρ⋈J — relational
        scoring without materializing J.  Delegates to the serving
        subsystem's compiled one-pass scorer (serving/compile.py); the
        seed per-leaf loop survives as serving.score_grouped_reference."""
        from ..serving import compile_ensemble, score_grouped

        # compile-once cache: the held tuple keeps strong refs to the
        # trees, so the id-based key cannot be reused by a different
        # (garbage-collected-then-reallocated) ensemble
        key = tuple(id(t) for t in trees)
        cached = getattr(self, "_compiled", None)
        if cached is None or cached[0] != key:
            ens = compile_ensemble(self.schema, trees, counter=self.counter)
            self._compiled = cached = (key, tuple(trees), ens)
        return score_grouped(cached[2], group_by)
