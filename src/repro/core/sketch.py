"""Count sketch / tensor sketch (paper §1.1.2, §3).

TensorSketch(v_1 ⊙ … ⊙ v_τ) uses τ independent 2-wise hash pairs
(h_t: [|D_t|] → [k], s_t: [|D_t|] → {±1}); the Kronecker coordinate
ρ = (j_1..j_τ) lands in bucket H(ρ) = Σ_t h_t(j_t) mod k with sign
Π_t s_t(j_t).  Inside a SumProd query this is exactly the polynomial
semiring: table t contributes the monomial s_t(w)·z^{h_t(w)} and ⊗
(circular convolution mod z^k) adds bucket indices and multiplies signs.

Two representations (DESIGN.md §3):
- coefficient space (:class:`~.semiring.PolyCoeff`) — faithful to the
  paper's FFT cost model;
- frequency space (:class:`~.semiring.PolyFreq`) — monomials have the
  analytic transform s·ω^{h·j} (ω = e^{-2πi/k}), ⊗ is O(k) elementwise;
  the classic Pham–Pagh trick, our beyond-paper optimization.

Hashes are Dietzfelbinger multiply-add-shift (2-approximately universal;
uint32 wraparound is the mod 2^32), generated from a PRNG key so the whole
pipeline is reproducible.  Bucket counts k are powers of two.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import jax
import jax.numpy as jnp

from .schema import Schema
from .semiring import PolyCoeff, PolyFreq


@dataclasses.dataclass(frozen=True)
class Hash2:
    """Multiply-add-shift hash into k = 2^M buckets plus a ±1 sign hash.

    h(x) = (a·x + b  mod 2^32) >> (32 - M), a odd — Dietzfelbinger et al.;
    s(x) = top bit of an independent copy, mapped to ±1.
    """

    a: jnp.ndarray
    b: jnp.ndarray
    a2: jnp.ndarray
    b2: jnp.ndarray
    k: int

    @staticmethod
    def make(key: jax.Array, k: int) -> "Hash2":
        assert k & (k - 1) == 0 and k > 1, "sketch size k must be a power of two"
        ka, kb, kc, kd = jax.random.split(key, 4)
        mk = lambda kk: jax.random.randint(kk, (), 0, jnp.iinfo(jnp.int32).max, dtype=jnp.int32).astype(jnp.uint32)
        return Hash2(
            a=mk(ka) * jnp.uint32(2) + jnp.uint32(1),   # odd
            b=mk(kb),
            a2=mk(kc) * jnp.uint32(2) + jnp.uint32(1),
            b2=mk(kd),
            k=k,
        )

    @property
    def _shift(self) -> int:
        return 32 - int(self.k).bit_length() + 1

    def bucket(self, x: jnp.ndarray) -> jnp.ndarray:
        v = self.a * x.astype(jnp.uint32) + self.b
        return (v >> jnp.uint32(self._shift)).astype(jnp.int32)

    def sign(self, x: jnp.ndarray) -> jnp.ndarray:
        v = self.a2 * x.astype(jnp.uint32) + self.b2
        return (1 - 2 * (v >> jnp.uint32(31)).astype(jnp.int32)).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class TableHashes:
    """One (h_t, s_t) pair per table, shared across a whole training run."""

    hashes: Dict[str, Hash2]
    k: int

    @staticmethod
    def make(key: jax.Array, schema: Schema, k: int) -> "TableHashes":
        keys = jax.random.split(key, schema.n_tables)
        return TableHashes(
            hashes={t.name: Hash2.make(kk, k) for t, kk in zip(schema.tables, keys)},
            k=k,
        )


def monomial_coeff(sem: PolyCoeff, signs: jnp.ndarray, buckets: jnp.ndarray):
    """s·z^h as a dense coefficient vector (…, k)."""
    oh = jax.nn.one_hot(buckets, sem.k, dtype=sem.dtype)
    return oh * signs[..., None]


def monomial_freq(sem: PolyFreq, signs: jnp.ndarray, buckets: jnp.ndarray):
    """rfft(s·z^h) = s·exp(-2πi·h·j/k), j = 0..k/2 — analytic, no FFT."""
    j = jnp.arange(sem.k // 2 + 1, dtype=jnp.float32)
    ang = -2.0 * jnp.pi * buckets[..., None].astype(jnp.float32) * j / sem.k
    return (signs[..., None] * jax.lax.complex(jnp.cos(ang), jnp.sin(ang))).astype(sem.dtype)


def sketch_factors(
    schema: Schema,
    sem,
    hashes: TableHashes,
    weight_table: str,
    weights: jnp.ndarray,
):
    """Per-table monomial factor arrays for one sketched SumProd query.

    Every table t contributes s_t(w_t(row))·z^{h_t(w_t(row))}; the
    designated ``weight_table`` additionally carries the real weight per
    row (the label x_y for Y', or the leaf prediction d_ℓ for Ŷ'; paper
    §3 puts F(x) on the last table — any single table works since ⊗ is
    commutative).
    """
    mono = monomial_freq if isinstance(sem, PolyFreq) else monomial_coeff
    factors = {}
    for t in schema.tables:
        h = hashes.hashes[t.name]
        w = schema.w_ids[t.name]
        m = mono(sem, h.sign(w), h.bucket(w))
        if t.name == weight_table:
            m = sem.scale(m, weights)
        factors[t.name] = m
    return factors


# ----------------------------------------------------------------------------
# Dense reference implementations (tests / benchmarks only)
# ----------------------------------------------------------------------------

def tensor_sketch_dense(vectors: Sequence[jnp.ndarray], hashes: Sequence[Hash2], k: int):
    """Directly sketch an explicit Kronecker product v_1 ⊙ … ⊙ v_τ.

    O(Π|D_t|) — test oracle for the SumProd-embedded sketch.
    """
    acc = None
    for v, h in zip(vectors, hashes):
        idx = jnp.arange(v.shape[0])
        contrib = jax.ops.segment_sum(
            v * h.sign(idx), h.bucket(idx), num_segments=k
        )
        f = jnp.fft.rfft(contrib, n=k)
        acc = f if acc is None else acc * f
    return jnp.fft.irfft(acc, n=k)


def count_sketch_dense(vec: jnp.ndarray, h: Hash2) -> jnp.ndarray:
    """Plain count sketch S·v of a dense vector (grad-compression oracle)."""
    idx = jnp.arange(vec.shape[0])
    return jax.ops.segment_sum(vec * h.sign(idx), h.bucket(idx), num_segments=h.k)
