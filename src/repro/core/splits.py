"""Split search: threshold sweep + MSE scoring (paper §2.1 / §2.2).

Given the grouped-by-``T_i`` per-row statistics at a batch of tree nodes
(counts n_ρ, residual sums r_ρ, residual squared sums rr_ρ — exact or
sketched), score every candidate ``(feature j of T_i, threshold α)`` with
the paper's closed form

    MSE(v,j,α) ∝ −( S_L²/n_L + S_R²/n_R )          (lower is better)

where S = Σ residuals on a side; the −S²/n form is exactly the paper's
``−1/n_v (s²/n + z²/m − …)`` with node-constant terms dropped.  Candidate
thresholds are the distinct values of the column (sort orders precomputed
once per schema — the paper's per-query O(n log n) sort amortizes away).
A quantile-histogram sweep (LightGBM-style) is a natural extension; the
exact sweep is what the paper specifies and what is implemented here.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .schema import Schema

NEG = -jnp.inf


def _argmax_band(scores: jnp.ndarray, axis: int,
                 rtol: float = 1e-5, atol: float = 1e-8) -> jnp.ndarray:
    """argmax that treats scores within an ulp-noise band of the max as
    TIED and picks the lowest index.  Mathematically tied candidates
    (e.g. two joined features inducing the same partition) acquire
    ulp-level score differences whose sign depends on the evaluation
    route (jitted vs eager, capacity-padded vs dense rows, message
    caching); a plain argmax then picks route-dependent splits.  The
    banded rule is deterministic across routes — the maintained and
    direct query engines provably select identical trees.  Applied at
    feature- and table-selection granularity (where cross-table joins
    genuinely duplicate partitions); the boundary sweep keeps a plain
    argmax — near-tied boundaries are distinct real candidates, and the
    materialized-join baseline must remain split-for-split comparable."""
    m = jnp.max(scores, axis=axis, keepdims=True)
    band = jnp.abs(m) * rtol + atol
    return jnp.argmax(scores >= m - band, axis=axis)


@dataclasses.dataclass(frozen=True)
class TableSplitPlan:
    """Static per-table artifacts for the sweep."""

    table: str
    order: jnp.ndarray        # (d_t, n) argsort per local feature
    sorted_vals: jnp.ndarray  # (d_t, n) column values in sorted order
    global_ids: jnp.ndarray   # (d_t,) global feature ids


def build_split_plans(
    schema: Schema,
    featmats: Optional[Dict[str, np.ndarray]] = None,
) -> Dict[str, TableSplitPlan]:
    """Static per-table sweep artifacts.  ``featmats`` overrides the
    schema's device-resident matrices (same columns, arbitrary row
    domain) — maintained engines pass capacity-shaped matrices whose
    dead slots sit at +inf, so they sort last and can never become
    thresholds (their stats are ⊕-zero either way)."""
    plans = {}
    for t in schema.tables:
        src = (featmats[t.name] if featmats is not None and t.name in featmats
               else schema.featmat[t.name])
        fm = np.asarray(src)                         # (n, d_t)
        if fm.shape[1] == 0:
            continue
        order = np.argsort(fm, axis=0, kind="stable").T.astype(np.int32)
        sv = np.take_along_axis(fm, order.T, axis=0).T
        gids = [
            g for g, (ti, _li) in enumerate(schema.feat_global)
            if schema.tables[ti].name == t.name
        ]
        plans[t.name] = TableSplitPlan(
            table=t.name,
            order=jnp.asarray(order),
            sorted_vals=jnp.asarray(sv),
            global_ids=jnp.asarray(np.asarray(gids, np.int32)),
        )
    return plans


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SplitResult:
    """Best split per node (all arrays (K,))."""

    score: jnp.ndarray       # gain score (higher = better), -inf if none
    feature: jnp.ndarray     # global feature id
    threshold: jnp.ndarray
    left_sum: jnp.ndarray    # Σ residual left
    left_cnt: jnp.ndarray
    right_sum: jnp.ndarray
    right_cnt: jnp.ndarray


def best_split_for_table(
    plan: TableSplitPlan,
    n: jnp.ndarray,    # (K, rows) counts per node per row-of-T_i
    s: jnp.ndarray,    # (K, rows) residual sums
) -> SplitResult:
    """Sweep all features of one table.  Score = S_L²/n_L + S_R²/n_R
    (monotone-equivalent to −MSE; node-constant terms dropped)."""

    tot_n = jnp.sum(n, axis=1)     # (K,)
    tot_s = jnp.sum(s, axis=1)

    def one_feature(fi):
        order = plan.order[fi]                      # (rows,)
        vals = plan.sorted_vals[fi]
        ns = jnp.take(n, order, axis=1)             # (K, rows)
        ss = jnp.take(s, order, axis=1)
        cln = jnp.cumsum(ns, axis=1)                # inclusive: left of boundary p+1
        cls = jnp.cumsum(ss, axis=1)
        # boundary after position p: threshold = vals[p+1]; valid iff value changes
        nl, sl = cln[:, :-1], cls[:, :-1]           # (K, rows-1)
        nr = tot_n[:, None] - nl
        srr = tot_s[:, None] - sl
        valid = (vals[1:] > vals[:-1])[None, :] & (nl > 0) & (nr > 0)
        score = jnp.where(
            valid,
            jnp.square(sl) / jnp.maximum(nl, 1e-9)
            + jnp.square(srr) / jnp.maximum(nr, 1e-9),
            NEG,
        )
        p = jnp.argmax(score, axis=1)               # (K,)
        take = lambda a: jnp.take_along_axis(a, p[:, None], axis=1)[:, 0]
        return (
            take(score),
            jnp.broadcast_to(vals[1:], score.shape)[jnp.arange(score.shape[0]), p],
            take(sl), take(nl), take(srr), take(nr),
        )

    d_t = plan.order.shape[0]
    res = jax.lax.map(one_feature, jnp.arange(d_t))
    scores = res[0]                                  # (d_t, K)
    fbest = _argmax_band(scores, axis=0)             # (K,) ties → lower gid
    pick = lambda a: jnp.take_along_axis(a, fbest[None, :], axis=0)[0]
    # subtract the no-split score so `score` is a true gain (≥ 0 when useful)
    base = jnp.square(tot_s) / jnp.maximum(tot_n, 1e-9)
    return SplitResult(
        score=pick(scores) - base,
        feature=jnp.take(plan.global_ids, fbest),
        threshold=pick(res[1]),
        left_sum=pick(res[2]),
        left_cnt=pick(res[3]),
        right_sum=pick(res[4]),
        right_cnt=pick(res[5]),
    )


def merge_table_results(results) -> SplitResult:
    """argmax across tables (ties — including ulp-level float ties — go
    to the earlier table, i.e. the lower global feature id)."""
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *results)
    best = _argmax_band(stacked.score, axis=0)       # (K,)
    take = lambda a: jnp.take_along_axis(a, best[None, :], axis=0)[0]
    return jax.tree.map(take, stacked)
