"""Split search: threshold sweep + MSE scoring (paper §2.1 / §2.2).

Given the grouped-by-``T_i`` per-row statistics at a batch of tree nodes
(counts n_ρ, residual sums r_ρ, residual squared sums rr_ρ — exact or
sketched), score every candidate ``(feature j of T_i, threshold α)`` with
the paper's closed form

    MSE(v,j,α) ∝ −( S_L²/n_L + S_R²/n_R )          (lower is better)

where S = Σ residuals on a side; the −S²/n form is exactly the paper's
``−1/n_v (s²/n + z²/m − …)`` with node-constant terms dropped.  Candidate
thresholds are the distinct values of the column (sort orders precomputed
once per schema — the paper's per-query O(n log n) sort amortizes away).
The exact sweep here is what the paper specifies and the default; the
quantile-histogram route (LightGBM-style, ``BoostConfig.split_mode =
"hist"``) lives in hist.py and shares this module's feature-selection
finisher — :func:`best_split_for_table` dispatches on the plan type.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .schema import Schema

NEG = -jnp.inf


def _argmax_band(scores: jnp.ndarray, axis: int,
                 rtol: float = 1e-5, atol: float = 1e-8) -> jnp.ndarray:
    """argmax that treats scores within an ulp-noise band of the max as
    TIED and picks the lowest index.  Mathematically tied candidates
    (e.g. two joined features inducing the same partition) acquire
    ulp-level score differences whose sign depends on the evaluation
    route (jitted vs eager, capacity-padded vs dense rows, message
    caching); a plain argmax then picks route-dependent splits.  The
    banded rule is deterministic across routes — the maintained and
    direct query engines provably select identical trees.  Applied at
    feature- and table-selection granularity (where cross-table joins
    genuinely duplicate partitions); the boundary sweep keeps a plain
    argmax — near-tied boundaries are distinct real candidates, and the
    materialized-join baseline must remain split-for-split comparable."""
    m = jnp.max(scores, axis=axis, keepdims=True)
    band = jnp.abs(m) * rtol + atol
    return jnp.argmax(scores >= m - band, axis=axis)


@dataclasses.dataclass(frozen=True)
class TableSplitPlan:
    """Static per-table artifacts for the sweep."""

    table: str
    order: jnp.ndarray        # (d_t, n) argsort per local feature
    sorted_vals: jnp.ndarray  # (d_t, n) column values in sorted order
    global_ids: jnp.ndarray   # (d_t,) global feature ids


def build_split_plans(
    schema: Schema,
    featmats: Optional[Dict[str, np.ndarray]] = None,
) -> Dict[str, TableSplitPlan]:
    """Static per-table sweep artifacts.  ``featmats`` overrides the
    schema's device-resident matrices (same columns, arbitrary row
    domain) — maintained engines pass capacity-shaped matrices whose
    dead slots sit at +inf, so they sort last and can never become
    thresholds (their stats are ⊕-zero either way)."""
    plans = {}
    for t in schema.tables:
        src = (featmats[t.name] if featmats is not None and t.name in featmats
               else schema.featmat[t.name])
        fm = np.asarray(src)                         # (n, d_t)
        if fm.shape[1] == 0:
            continue
        order = np.argsort(fm, axis=0, kind="stable").T.astype(np.int32)
        sv = np.take_along_axis(fm, order.T, axis=0).T
        gids = [
            g for g, (ti, _li) in enumerate(schema.feat_global)
            if schema.tables[ti].name == t.name
        ]
        plans[t.name] = TableSplitPlan(
            table=t.name,
            order=jnp.asarray(order),
            sorted_vals=jnp.asarray(sv),
            global_ids=jnp.asarray(np.asarray(gids, np.int32)),
        )
    return plans


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SplitResult:
    """Best split per node (all arrays (K,))."""

    score: jnp.ndarray       # gain score (higher = better), -inf if none
    feature: jnp.ndarray     # global feature id
    threshold: jnp.ndarray
    left_sum: jnp.ndarray    # Σ residual left
    left_cnt: jnp.ndarray
    right_sum: jnp.ndarray
    right_cnt: jnp.ndarray


def score_boundaries(nl, sl, nr, sr, valid, thr_vals):
    """Shared boundary scorer for both sweep routes: per-boundary left/
    right stats ((K, d_t, nb) arrays, ``thr_vals`` broadcastable to
    them) → per-(node, feature) best-boundary parts, each (K, d_t).
    One implementation keeps the gain formula, epsilon, and invalid
    sentinel identical across routes — the exact/hist parity the
    differential tests pin depends on it."""
    score = jnp.where(
        valid,
        jnp.square(sl) / jnp.maximum(nl, 1e-9)
        + jnp.square(sr) / jnp.maximum(nr, 1e-9),
        NEG,
    )
    p = jnp.argmax(score, axis=2)                    # (K, d_t)
    take = lambda a: jnp.take_along_axis(a, p[..., None], axis=2)[..., 0]
    thr = jnp.take_along_axis(
        jnp.broadcast_to(thr_vals, score.shape), p[..., None], axis=2
    )[..., 0]
    return take(score), thr, take(sl), take(nl), take(sr), take(nr)


# peak-memory budget for the exact sweep's (K, block, rows) intermediates;
# one block for every workload in the repo, a bounded unrolled loop beyond
_EXACT_BLOCK_ELEMS = 1 << 25


def _exact_scores(plan: TableSplitPlan, n, s, tot_n, tot_s):
    """Exact sweep, batched over the feature axis: a (K, d_t, rows)
    gather + cumsum scores every boundary of every feature at once (the
    per-feature ``lax.map`` this replaces serialized an embarrassingly
    parallel scan).  Very wide×tall tables process the feature axis in
    blocks so peak memory stays bounded — within a block the sweep is
    fully batched, and per-feature results are independent so blocking
    cannot change them.  Returns per-(node, feature) best-boundary
    arrays (score, thr, sl, nl, sr, nr), each (K, d_t)."""
    d_t, rows = plan.order.shape
    K = n.shape[0]
    block = max(1, _EXACT_BLOCK_ELEMS // max(K * rows, 1))

    def sweep(order, vals):                          # (block, rows) each
        ns = jnp.take(n, order, axis=1)              # (K, block, rows)
        ss = jnp.take(s, order, axis=1)
        cln = jnp.cumsum(ns, axis=2)                 # inclusive: left of boundary p+1
        cls = jnp.cumsum(ss, axis=2)
        # boundary after position p: threshold = vals[p+1]; valid iff value changes
        nl, sl = cln[..., :-1], cls[..., :-1]        # (K, block, rows-1)
        nr = tot_n[:, None, None] - nl
        sr = tot_s[:, None, None] - sl
        valid = (vals[:, 1:] > vals[:, :-1])[None] & (nl > 0) & (nr > 0)
        return score_boundaries(nl, sl, nr, sr, valid, vals[None, :, 1:])

    if block >= d_t:
        return sweep(plan.order, plan.sorted_vals)
    parts = [
        sweep(plan.order[f0:f0 + block], plan.sorted_vals[f0:f0 + block])
        for f0 in range(0, d_t, block)
    ]
    return tuple(jnp.concatenate(ps, axis=1) for ps in zip(*parts))


def _best_feature(plan, scores, thr, sl, nl, sr, nr, tot_n, tot_s) -> SplitResult:
    """Shared finisher for both sweep routes: per-(node, feature) best
    boundaries ((K, d_t) arrays) → the per-node winning feature (banded
    argmax, ties to the lower global feature id)."""
    fbest = _argmax_band(scores, axis=1)             # (K,)
    pick = lambda a: jnp.take_along_axis(a, fbest[:, None], axis=1)[:, 0]
    # subtract the no-split score so `score` is a true gain (≥ 0 when useful)
    base = jnp.square(tot_s) / jnp.maximum(tot_n, 1e-9)
    return SplitResult(
        score=pick(scores) - base,
        feature=jnp.take(plan.global_ids, fbest),
        threshold=pick(thr),
        left_sum=pick(sl),
        left_cnt=pick(nl),
        right_sum=pick(sr),
        right_cnt=pick(nr),
    )


def best_split_for_table(
    plan,              # TableSplitPlan (exact) | hist.TableHistPlan
    n: jnp.ndarray,    # (K, rows) counts per node per row-of-T_i
    s: jnp.ndarray,    # (K, rows) residual sums
) -> SplitResult:
    """Sweep all features of one table.  Score = S_L²/n_L + S_R²/n_R
    (monotone-equivalent to −MSE; node-constant terms dropped).  The
    route is chosen by the plan type: exact boundary sweep over argsort
    orders, or the quantile-histogram sweep (hist.py) over maintained
    bin maps."""
    from .hist import TableHistPlan, hist_scores

    tot_n = jnp.sum(n, axis=1)     # (K,)
    tot_s = jnp.sum(s, axis=1)
    if isinstance(plan, TableHistPlan):
        parts = hist_scores(plan, n, s, tot_n, tot_s)
    else:
        parts = _exact_scores(plan, n, s, tot_n, tot_s)
    return _best_feature(plan, *parts, tot_n, tot_s)


def merge_table_results(results) -> SplitResult:
    """argmax across tables (ties — including ulp-level float ties — go
    to the earlier table, i.e. the lower global feature id)."""
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *results)
    best = _argmax_band(stacked.score, axis=0)       # (K,)
    take = lambda a: jnp.take_along_axis(a, best[None, :], axis=0)[0]
    return jax.tree.map(take, stacked)
