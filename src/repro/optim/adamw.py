"""Sharded AdamW + schedules.  Optimizer state lives in fp32 and inherits
each parameter's sharding (ZeRO-3-like: fully sharded moments).  Optional
fp32 master params for long runs (memory permitting — see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    master_fp32: bool = False


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    master: Any  # fp32 params or () when disabled


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(cfg: AdamWConfig, params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = (
        jax.tree.map(lambda p: p.astype(jnp.float32), params)
        if cfg.master_fp32 else ()
    )
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros), master=master)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def apply(cfg: AdamWConfig, params, grads, state: OptState):
    """One AdamW step (grads already averaged).  Returns (params, state, stats)."""
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, mp):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        base = mp if cfg.master_fp32 else p.astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * base)
        return new.astype(p.dtype), m, v, new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_mp = jax.tree.leaves(state.master) if cfg.master_fp32 else flat_p
    outs = [upd(*t) for t in zip(flat_p, flat_g, flat_m, flat_v, flat_mp)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    new_master = treedef.unflatten([o[3] for o in outs]) if cfg.master_fp32 else ()
    return new_p, OptState(step, new_m, new_v, new_master), {"grad_norm": gn, "lr": lr}
