"""Count-sketch gradient compression — the paper's sketch machinery as a
distributed-optimization trick (DESIGN.md §5.2).

Cross-pod gradient reduction is the bandwidth cliff at multi-pod scale
(DCI ≪ ICI).  Each pod count-sketches its gradient leaf g into k ≪ |g|
buckets (S·g with the same 2-universal (h, s) hashes as core/sketch —
Thm 1.2's AMM property bounds the inner-product distortion of the
sketched sum); pods all-reduce only the sketches, then unsketch the
unbiased estimate ĝ_i = s(i)·sketch[h(i)].  Local *error feedback*
(Karimireddy et al. 2019) accumulates the per-step compression residual
so the scheme converges like SGD on the uncompressed gradient.

This module provides the single-process computational core (compress /
decompress / error feedback); the cross-pod psum of sketches is a plain
``lax.psum`` over the "pod" axis wherever train_step runs under
shard_map.  Used as the optional `compressor` hook of make_train_step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.sketch import Hash2


@dataclasses.dataclass
class CountSketchCompressor:
    """ratio: |g| / k compression per leaf.  Stateful (error feedback)."""

    ratio: int = 8
    seed: int = 0
    error_feedback: bool = True
    _state: Optional[Any] = None
    _round: int = 0

    def _leaf_hash(self, i: int, n: int) -> Hash2:
        """Fresh hashes every round: a fixed sketch is a fixed rank-k
        projector whose nullspace error feedback can never transmit;
        rotating (h, s) per step restores full-space convergence
        (SketchedSGD practice)."""
        k = max(2, 1 << max(1, (n // self.ratio)).bit_length())
        k = min(k, 1 << max(1, n.bit_length()))
        key = jax.random.PRNGKey(self.seed)
        key = jax.random.fold_in(jax.random.fold_in(key, i), self._round)
        return Hash2.make(key, k)

    def __call__(self, grads):
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        if self._state is None:
            self._state = [jnp.zeros_like(l.reshape(-1)) for l in leaves]
        out = []
        new_state = []
        for i, leaf in enumerate(leaves):
            flat = leaf.reshape(-1)
            n = flat.shape[0]
            if n < 4 * self.ratio:       # tiny leaves: send uncompressed
                out.append(leaf)
                new_state.append(jnp.zeros_like(flat))
                continue
            h = self._leaf_hash(i, n)
            idx = jnp.arange(n)
            sign = h.sign(idx)
            buckets = h.bucket(idx)
            x = flat + (self._state[i] if self.error_feedback else 0.0)
            sk = jax.ops.segment_sum(x * sign, buckets, num_segments=h.k)
            # (cross-pod psum of `sk` happens here in the sharded setting)
            est = sign * jnp.take(sk, buckets)
            if self.error_feedback:
                # EF needs a *contractive* compressor: the raw unsketch has
                # collision noise E‖ξ‖² ≈ (n/k−1)‖x‖²; scaling by k/n gives
                # ‖x − C(x)‖² = (1 − k/n)‖x‖² — the optimal linear shrink
                est = est * (h.k / n)
            new_state.append(x - est if self.error_feedback else jnp.zeros_like(flat))
            out.append(est.reshape(leaf.shape))
        self._state = new_state
        self._round += 1
        return jax.tree_util.tree_unflatten(treedef, out)

    def compressed_bytes(self, grads) -> int:
        total = 0
        for i, leaf in enumerate(jax.tree_util.tree_leaves(grads)):
            n = leaf.size
            total += (n if n < 4 * self.ratio else self._leaf_hash(i, n).k) * 4
        return total
