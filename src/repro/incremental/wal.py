"""Durable write-ahead log of :class:`TableDelta` batches.

The dynamic store (:mod:`repro.incremental.state`) keeps everything in
process memory: a crash loses every applied delta, and a serving
replica in another process has no way to observe the writer's stream.
This module gives the delta stream the durability story databases give
theirs (the machinery JoinBoost leans on, see PAPERS.md):

- :class:`WalWriter` — an append-only, length-prefixed,
  CRC32-checksummed log of encoded delta batches.  One record per
  applied batch; the record's LSN **is** the ``data_version`` the batch
  produced, so the log and the in-memory version counter can never
  disagree about what a version means.  ``fsync`` is batched
  (``sync_every`` records / ``sync_interval_s`` seconds) — the
  classic group-commit trade: bounded loss window, negligible
  per-append cost.
- :class:`WalReader` / :func:`read_records` — replay with torn-tail
  semantics: a short header, short payload, or CRC mismatch at the tail
  is *expected* after a crash (a record was mid-write) and cleanly ends
  the stream at the last valid LSN; the same corruption anywhere before
  the tail raises :class:`WalCorruptError` (bit rot, not a torn write).
- :class:`WalFollower` — a tailing reader on its own thread that drives
  a read-only replica (any ``apply(deltas)`` consumer, e.g. a
  :class:`~repro.incremental.maintain.MaintainedScorer`) in another
  process than the writer.  A checksum-invalid tail is retried with
  jittered backoff (it is usually an in-flight append); the follower
  keeps serving its last applied version while the log lags or the
  writer dies — replication lag is exported for the SLO staleness
  objective to burn against (degraded, not dead).

Attachment: ``WalWriter.attach(state)`` sets ``state.wal``;
:meth:`DynamicState.apply` then logs every batch *under the existing
state lock*, after the mutations succeed and immediately before the
``data_version`` bump — so the log contains exactly the committed
versions, in order, and a concurrent snapshot can never observe a
version the log will not eventually carry.

Record layout (little-endian)::

    file   := magic(8B = b"RBRTWAL1") record*
    record := u32 payload_len | u32 crc32(payload) | payload
    payload: json header (lsn, wall time, array descriptors)
             + concatenated raw array bytes

Fault injection: every durability-relevant step calls
``fault(point, ...)`` on the injected :class:`FaultPlan`-like hook
(``tests/_faultfs.py``), which can raise ``CrashPoint`` — or tear an
append mid-buffer — to simulate process death at that exact point.
"""
from __future__ import annotations

import json
import os
import struct
import threading
import time
import zlib
from typing import Callable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import get_registry
from ..runtime.fault import Backoff
from .deltas import TableDelta

__all__ = [
    "MAGIC", "WalCorruptError", "WalWriter", "WalReader", "WalFollower",
    "encode_record", "decode_record", "read_records", "scan_wal", "wal_path",
]

MAGIC = b"RBRTWAL1"
_HDR = struct.Struct("<II")              # payload_len, crc32


class WalCorruptError(RuntimeError):
    """Checksum/structure failure NOT at the tail — real corruption."""


def wal_path(wal_dir: str) -> str:
    return os.path.join(wal_dir, "wal.log")


# ------------------------------------------------------------------ codec --
def _arr_token(name: str, a: np.ndarray, blobs: List[bytes]) -> dict:
    a = np.ascontiguousarray(a)
    blobs.append(a.tobytes())
    return {"n": name, "d": a.dtype.str, "s": list(a.shape),
            "b": len(blobs[-1])}


def encode_record(lsn: int, deltas: Sequence[TableDelta],
                  t_wall: Optional[float] = None) -> bytes:
    """One applied batch → payload bytes (json header + raw arrays).

    The encoding is exact: dtypes and shapes round-trip bit-for-bit, so
    a replayed delta is indistinguishable from the original (the
    recovery bit-equality invariant depends on this).
    """
    if isinstance(deltas, TableDelta):
        deltas = [deltas]
    blobs: List[bytes] = []
    ds = []
    for d in deltas:
        ins = upd = dele = None
        if d.inserts:
            ins = [_arr_token(c, np.asarray(v), blobs)
                   for c, v in d.inserts.items()]
        if d.deletes is not None:
            dele = _arr_token("", np.asarray(d.deletes), blobs)
        if d.updates is not None:
            slots, cols = d.updates
            upd = {"slots": _arr_token("", np.asarray(slots), blobs),
                   "cols": [_arr_token(c, np.asarray(v), blobs)
                            for c, v in cols.items()]}
        ds.append({"t": d.table, "i": ins, "x": dele, "u": upd})
    head = json.dumps({
        "lsn": int(lsn),
        "tw": time.time() if t_wall is None else t_wall,
        "ds": ds,
    }).encode()
    return struct.pack("<I", len(head)) + head + b"".join(blobs)


def decode_record(payload: bytes) -> Tuple[int, List[TableDelta], float]:
    """Inverse of :func:`encode_record` → (lsn, deltas, wall time)."""
    (hlen,) = struct.unpack_from("<I", payload)
    head = json.loads(payload[4:4 + hlen].decode())
    off = 4 + hlen

    def take(tok) -> np.ndarray:
        nonlocal off
        a = np.frombuffer(payload[off:off + tok["b"]],
                          dtype=np.dtype(tok["d"])).reshape(tok["s"])
        off += tok["b"]
        return a.copy()                  # writable, detached from payload

    deltas = []
    for d in head["ds"]:
        inserts = ({t["n"]: take(t) for t in d["i"]}
                   if d["i"] is not None else None)
        deletes = take(d["x"]) if d["x"] is not None else None
        updates = None
        if d["u"] is not None:
            slots = take(d["u"]["slots"])
            updates = (slots, {t["n"]: take(t) for t in d["u"]["cols"]})
        deltas.append(TableDelta(table=d["t"], inserts=inserts,
                                 deletes=deletes, updates=updates))
    return int(head["lsn"]), deltas, float(head["tw"])


# ----------------------------------------------------------------- writer --
class WalWriter:
    """Append-only durable log, one record per applied delta batch.

    ``sync_every`` / ``sync_interval_s`` batch the fsync (group
    commit): an append is acknowledged once buffered to the OS; the
    durability horizon is the last sync.  ``sync_every=1`` gives
    per-record durability for the crash tests.  Thread-safe — appends
    normally arrive under ``state.lock`` already, but the writer keeps
    its own lock so direct use (e.g. the benchmarks) is safe too.

    ``fault`` is the fault-injection hook: called at each durability
    point (``append.before`` / ``append.write`` / ``append.after`` /
    ``sync.before`` / ``sync.after``) and may raise to simulate a
    crash; ``append.write`` additionally lets the plan tear the buffer
    (write a prefix, then die).
    """

    def __init__(self, wal_dir: str, sync_every: int = 8,
                 sync_interval_s: float = 0.05,
                 fault: Optional[Callable] = None, repair: bool = False):
        self.dir = wal_dir
        os.makedirs(wal_dir, exist_ok=True)
        self.path = wal_path(wal_dir)
        self.sync_every = max(1, int(sync_every))
        self.sync_interval_s = sync_interval_s
        self.fault = fault
        self._lock = threading.Lock()
        self._unsynced = 0
        self._last_sync = time.perf_counter()
        fresh = not os.path.exists(self.path) or os.path.getsize(self.path) == 0
        if fresh:
            with open(self.path, "ab") as f:
                f.write(MAGIC)
                f.flush()
                os.fsync(f.fileno())
        last, valid_end, size = scan_wal(self.path)
        if valid_end < size:
            # trailing bytes that don't checksum: a torn append from a
            # crashed writer.  Appending AFTER them would bury garbage
            # mid-log — repair (truncate at the last valid record) or
            # refuse, never continue past it.
            if not repair:
                raise WalCorruptError(
                    f"{self.path}: {size - valid_end} invalid tail bytes — "
                    f"recover first (repro.incremental.recover) or open "
                    f"with repair=True to truncate them")
            with open(self.path, "r+b") as f:
                f.truncate(valid_end)
                if valid_end < len(MAGIC):   # torn file header: restart file
                    f.truncate(0)
                    f.seek(0)
                    f.write(MAGIC)
                f.flush()
                os.fsync(f.fileno())
            get_registry().counter("wal.tail_bytes_discarded").inc(
                size - valid_end)
        self._f = open(self.path, "ab")
        self.last_lsn = last
        self.synced_lsn = self.last_lsn
        reg = get_registry()
        self._c_appends = reg.counter("wal.appends")
        self._c_syncs = reg.counter("wal.syncs")
        self._h_append_ms = reg.histogram("wal.append_ms")
        self._g_synced = reg.gauge("wal.synced_lsn")
        self._g_synced.set(self.synced_lsn)

    def _fault(self, point: str, **ctx):
        if self.fault is not None:
            self.fault(point, **ctx)

    # ------------------------------------------------------------- append --
    def append(self, lsn: int, deltas: Sequence[TableDelta]) -> int:
        """Log one batch as ``lsn`` (must be ``last_lsn + 1``).  Returns
        the byte offset of the record's end."""
        t0 = time.perf_counter()
        with self._lock:
            if lsn != self.last_lsn + 1:
                raise ValueError(
                    f"non-monotonic append: lsn {lsn} after {self.last_lsn}")
            payload = encode_record(lsn, deltas)
            buf = _HDR.pack(len(payload), zlib.crc32(payload)) + payload
            self._fault("append.before", lsn=lsn)
            torn = None
            if self.fault is not None:
                torn = self.fault("append.write", lsn=lsn, buf=buf)
            if torn is not None:                 # injected torn write
                self._f.write(buf[:torn])
                self._f.flush()
                os.fsync(self._f.fileno())
                raise _crashpoint(f"torn append at lsn {lsn} ({torn} bytes)")
            self._f.write(buf)
            self._f.flush()                      # to the OS, not the disk
            self.last_lsn = lsn
            self._unsynced += 1
            self._fault("append.after", lsn=lsn)
            now = time.perf_counter()
            if (self._unsynced >= self.sync_every
                    or now - self._last_sync >= self.sync_interval_s):
                self._sync_locked()
            end = self._f.tell()
        self._c_appends.inc()
        self._h_append_ms.observe((time.perf_counter() - t0) * 1e3)
        return end

    def sync(self) -> int:
        """Force-fsync the log; returns the durable LSN."""
        with self._lock:
            self._sync_locked()
            return self.synced_lsn

    def heartbeat(self) -> None:
        """Append a liveness marker (LSN 0, no deltas) and sync it.

        Followers use record wall times to judge writer liveness; an
        idle-but-alive writer heartbeats so its replicas can tell
        "nothing to replicate" apart from "writer died" and degrade
        only in the second case."""
        with self._lock:
            payload = encode_record(0, [])
            buf = _HDR.pack(len(payload), zlib.crc32(payload)) + payload
            self._f.write(buf)
            self._f.flush()
            self._sync_locked()

    def _sync_locked(self):
        self._fault("sync.before", lsn=self.last_lsn)
        os.fsync(self._f.fileno())
        self.synced_lsn = self.last_lsn
        self._unsynced = 0
        self._last_sync = time.perf_counter()
        self._fault("sync.after", lsn=self.last_lsn)
        self._c_syncs.inc()
        self._g_synced.set(self.synced_lsn)

    def close(self):
        with self._lock:
            if not self._f.closed:
                os.fsync(self._f.fileno())
                self._f.close()

    # --------------------------------------------------------- attachment --
    def attach(self, state) -> "WalWriter":
        """Hook this log into a :class:`DynamicState`: every ``apply``
        appends its batch (under ``state.lock``, post-mutation,
        pre-version-bump) with ``lsn == the new data_version``."""
        if state.data_version != self.last_lsn:
            raise ValueError(
                f"state at data_version {state.data_version} but log ends "
                f"at lsn {self.last_lsn} — recover first, then attach")
        state.wal = self
        return self


def _crashpoint(msg: str):
    """Late import so src/ never depends on tests/: the torn-write path
    only runs under injection, where tests/_faultfs is importable."""
    try:
        from _faultfs import CrashPoint          # type: ignore
        return CrashPoint(msg)
    except ImportError:                          # pragma: no cover
        return RuntimeError(msg)


# ----------------------------------------------------------------- reader --
def read_records(path: str, start_offset: int = 0
                 ) -> Iterator[Tuple[int, List[TableDelta], float, int]]:
    """Yield ``(lsn, deltas, t_wall, end_offset)`` for every valid record.

    Ends cleanly at a torn/truncated/corrupt TAIL record (the crash
    signature); raises :class:`WalCorruptError` if a corrupt record is
    followed by more bytes that parse — that is mid-log damage replay
    must not silently skip.
    """
    with open(path, "rb") as f:
        if start_offset:
            f.seek(start_offset)
        else:
            magic = f.read(len(MAGIC))
            if len(magic) < len(MAGIC):
                return                    # torn file header (crash at create)
            if magic != MAGIC:
                raise WalCorruptError(f"{path}: bad magic {magic!r}")
        pending_err: Optional[str] = None
        while True:
            hdr = f.read(_HDR.size)
            if not hdr:
                return                        # clean EOF
            if len(hdr) < _HDR.size:
                return                        # torn header at tail
            plen, crc = _HDR.unpack(hdr)
            payload = f.read(plen)
            if len(payload) < plen:
                return                        # torn payload at tail
            if zlib.crc32(payload) != crc:
                # only a tail record may be invalid; probe for more data
                if f.read(1):
                    raise WalCorruptError(
                        f"{path}: checksum failure before EOF "
                        f"(mid-log corruption)")
                return
            try:
                lsn, deltas, tw = decode_record(payload)
            except Exception as e:            # valid CRC, bad structure
                raise WalCorruptError(f"{path}: undecodable record: {e}")
            yield lsn, deltas, tw, f.tell()


def scan_wal(path: str) -> Tuple[int, int, int]:
    """Walk the whole log → ``(last_lsn, valid_end_offset, file_size)``.

    ``last_lsn`` is the newest delta record's LSN (heartbeats ignored);
    ``valid_end_offset`` is where the last checksum-valid record ends —
    anything between it and ``file_size`` is a torn/corrupt tail.
    Raises :class:`WalCorruptError` on mid-log damage.
    """
    size = os.path.getsize(path)
    if size < len(MAGIC):
        return 0, 0, size                # torn at creation: no valid prefix
    last = 0
    end = len(MAGIC)
    for lsn, _, _, off in read_records(path):
        if lsn:
            last = lsn
        end = off
    return last, end, os.path.getsize(path)


class WalReader:
    """Stateful tail-reader over one log file (follower building block).

    :meth:`poll` yields any NEW complete, checksum-valid records past
    the last read offset and remembers where it stopped; an invalid
    tail is left un-consumed (the writer may still be appending it) and
    simply yields nothing this round.
    """

    def __init__(self, wal_dir: str):
        self.path = wal_path(wal_dir)
        self.offset = 0
        self.last_lsn = 0

    def poll(self) -> List[Tuple[int, List[TableDelta], float]]:
        if not os.path.exists(self.path):
            return []
        if self.offset == 0:
            with open(self.path, "rb") as f:
                magic = f.read(len(MAGIC))
            if len(magic) < len(MAGIC):
                return []                     # header mid-write
            if magic != MAGIC:
                raise WalCorruptError(f"{self.path}: bad magic {magic!r}")
            self.offset = len(MAGIC)
        out = []
        for lsn, deltas, tw, end in read_records(self.path, self.offset):
            if lsn:                              # lsn 0 = heartbeat
                if self.last_lsn and lsn != self.last_lsn + 1:
                    raise WalCorruptError(
                        f"{self.path}: lsn gap {self.last_lsn} → {lsn}")
                self.last_lsn = lsn
            self.offset = end
            out.append((lsn, deltas, tw))
        return out


# --------------------------------------------------------------- follower --
class WalFollower:
    """Tail a writer's log from another process and drive a replica.

    ``apply_fn(deltas)`` is called once per record, in LSN order —
    typically ``MaintainedScorer.apply`` on a read-only replica.  The
    loop polls at ``poll_interval_s`` and, when a poll errors (an
    in-flight append read mid-write, a transient IO failure), retries
    with the jittered :class:`~repro.runtime.fault.Backoff` rather than
    tearing the replica down.

    Liveness: ``replication_lag_s()`` is the age of the newest record
    the replica has NOT yet applied (0 while caught up).  While the
    writer is down the log stops growing, the lag reads 0 once drained,
    and ``writer_idle_s()`` grows instead — the serving CLI feeds
    ``max(scorer staleness, replication lag)`` to the SLO staleness
    objective, so a dead writer degrades the replica (serve stale) but
    never kills it.
    """

    def __init__(self, wal_dir: str, apply_fn: Callable, start_lsn: int = 0,
                 poll_interval_s: float = 0.01,
                 backoff: Optional[Backoff] = None):
        self.reader = WalReader(wal_dir)
        self.apply_fn = apply_fn
        self.start_lsn = start_lsn
        self.poll_interval_s = poll_interval_s
        self.backoff = backoff if backoff is not None else Backoff(
            base_s=0.01, cap_s=0.5, budget_s=30.0)
        self.applied_lsn = start_lsn
        self._pending = False            # undrained bytes past the offset
        self._last_record_wall = None    # wall time of newest seen record
        self._t_started = time.time()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None
        reg = get_registry()
        self._c_applied = reg.counter("wal.follower.applied")
        self._c_retries = reg.counter("wal.follower.retries")
        self._g_lag = reg.gauge("wal.follower.lag_s")
        self._g_lsn = reg.gauge("wal.follower.applied_lsn")
        self.apply_lag_s = reg.histogram("wal.follower.apply_lag_s")

    # -------------------------------------------------------------- status --
    def replication_lag_s(self) -> float:
        """Seconds the replica trails the newest durable record: 0 when
        fully caught up (including a dead writer whose drained log has
        simply stopped growing); while bytes sit unread past our offset
        the lag is approximated by time since the last applied record
        (the pending record's own timestamp is unreadable until its
        write completes)."""
        if not self._pending:
            return 0.0
        base = self._last_record_wall
        return max(0.0, time.time() - (base if base is not None
                                       else self._t_started))

    def writer_idle_s(self) -> float:
        """Seconds since the writer last wrote ANYTHING (delta record or
        heartbeat) — the liveness signal: growth past the writer's
        heartbeat cadence means it likely died.  0 before any record."""
        if self._last_record_wall is None:
            return 0.0
        return max(0.0, time.time() - self._last_record_wall)

    # ------------------------------------------------------------ tail loop --
    def step(self) -> int:
        """One poll+apply round (also the synchronous test surface).
        Returns the number of records applied."""
        records = self.reader.poll()
        n = 0
        for lsn, deltas, tw in records:
            self._last_record_wall = max(self._last_record_wall or tw, tw)
            if lsn == 0 or lsn <= self.start_lsn:
                continue                 # heartbeat / below the checkpoint
            if lsn != self.applied_lsn + 1:
                raise WalCorruptError(
                    f"follower lsn gap: {self.applied_lsn} → {lsn}")
            self.apply_fn(deltas)
            self.applied_lsn = lsn
            self.apply_lag_s.observe(max(0.0, time.time() - tw))
            self._c_applied.inc()
            n += 1
        try:                             # undrained tail (e.g. mid-write)?
            size = os.path.getsize(self.reader.path)
        except OSError:
            size = self.reader.offset
        self._pending = size > self.reader.offset
        self._g_lag.set(self.replication_lag_s())
        self._g_lsn.set(self.applied_lsn)
        return n

    def _run(self):
        retry = self.backoff.clone()
        while not self._stop.is_set():
            try:
                self.step()
                retry.reset()
                self._stop.wait(self.poll_interval_s)
            except WalCorruptError:
                # possibly an append observed mid-write; back off and
                # re-poll — if it never heals the budget expires
                self._c_retries.inc()
                try:
                    delay = retry.next_delay()
                except RuntimeError as e:
                    self.error = e
                    return
                self._stop.wait(delay)
            except BaseException as e:   # replica apply blew up: stop
                self.error = e
                return

    def start(self) -> "WalFollower":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()
        return self

    def stop(self, drain: bool = True):
        if self._thread is not None:
            self._stop.set()
            self._thread.join()
            self._thread = None
        if drain and self.error is None:
            self.step()                  # pick up the final records
        if self.error is not None:
            raise self.error
