"""Checkpoint + WAL-tail recovery for the dynamic relational store.

Recovery contract (the invariant every fault-injection test pins):
after ANY crash — torn append, bit-flipped tail, death at any
checkpoint/rename step, SIGKILL mid-stream — recovery lands on a valid
LSN ``L`` (the newest durable version), and the recovered
:class:`~repro.incremental.state.DynamicState` scores **bit-equal** to
the pinned recompute oracle at ``data_version == L``.

Checkpoints reuse the atomic publication pattern of
``checkpoint/checkpointer.py`` (tmp dir → fsync'd files → rename →
``LATEST`` pointer replaced last), but serialize the *dynamic* store —
capacity-padded columns, liveness masks, append-only key dictionaries,
version counters — as plain ``.npy`` files with per-file CRC32s in the
manifest, so a bit-flipped checkpoint is detected and recovery falls
back to the previous one (plus a longer WAL replay) instead of loading
garbage.

Layout::

    <ckpt_dir>/ckpt_<lsn>/
        manifest.json        versions, capacities, edge specs, file CRCs
        t.<table>.<col>.npy  one file per column (full capacity)
        t.<table>.live.npy   liveness mask
        e<i>.key<j>.npy      edge i's key dictionary, column j, id order
        e<i>.ids.<table>.npy maintained key-id array per incident table
    <ckpt_dir>/LATEST        newest lsn (written last, replaced atomically)

Entry points:

- :func:`save_checkpoint` — atomic snapshot of a live state (captured
  under ``state.lock``), with retention GC.
- :func:`recover_state` — newest valid checkpoint + replay of the WAL
  tail, torn tail discarded at the last valid LSN.
- :func:`recover_scorer` — the same, rebuilt into a fresh
  :class:`~repro.incremental.maintain.MaintainedScorer` (factor rows
  re-evaluated for the recovered live slots; replay runs through
  ``scorer.apply`` so maintained factors stay exact).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
import zlib
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..obs import get_registry
from ..core.schema import Schema
from .deltas import DynamicEdge, DynamicTable
from .state import DynamicState
from .wal import MAGIC, WalCorruptError, read_records, wal_path

__all__ = [
    "RecoveryError", "RecoveryReport",
    "save_checkpoint", "load_checkpoint", "latest_checkpoint_lsn",
    "recover_state", "recover_scorer",
]

_FORMAT = 1


class RecoveryError(RuntimeError):
    """Unrecoverable inconsistency (e.g. an LSN gap between the newest
    valid checkpoint and the first WAL record after it)."""


@dataclasses.dataclass
class RecoveryReport:
    """What one recovery did — the evidence trail the tests assert on."""

    checkpoint_lsn: int          # 0 = no usable checkpoint (fresh state)
    recovered_lsn: int           # final data_version after tail replay
    replayed: int                # WAL records applied past the checkpoint
    tail_bytes_discarded: int    # torn/corrupt tail dropped at recovery
    checkpoints_skipped: int     # invalid checkpoints skipped (bit rot)
    replay_s: float


def _crc(path: str) -> int:
    with open(path, "rb") as f:
        return zlib.crc32(f.read())


def _fault_call(fault: Optional[Callable], point: str, **ctx):
    if fault is not None:
        fault(point, **ctx)


# ------------------------------------------------------------------- save --
def save_checkpoint(state: DynamicState, ckpt_dir: str, keep: int = 3,
                    fault: Optional[Callable] = None) -> str:
    """Atomically publish ``<ckpt_dir>/ckpt_<data_version>``.

    The snapshot is captured under ``state.lock`` (column/mask/id
    copies), so it is one consistent version even while a writer keeps
    applying.  Publication order — files, fsync, dir rename, ``LATEST``
    replace — means a crash at ANY point leaves either the previous
    checkpoint set intact or the new one fully visible; fault points
    (``ckpt.before_rename`` / ``ckpt.after_rename`` / ``ckpt.after``)
    let the tests die at each step and prove it.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    with state.lock:
        lsn = state.data_version
        jtv = state.jt_version
        cols = {t: {c: v.copy() for c, v in dt.columns.items()}
                for t, dt in state.tables.items()}
        live = {t: dt.live.copy() for t, dt in state.tables.items()}
        caps = {t: dt.capacity for t, dt in state.tables.items()}
        edges = []
        for key, e in state.edges.items():
            keys_mat = None
            if e.key_to_id:
                # insertion order IS the id order: row i of the matrix
                # is the key tuple with id i
                ordered = sorted(e.key_to_id.items(), key=lambda kv: kv[1])
                keys_mat = [np.asarray([k[j] for k, _ in ordered])
                            for j in range(len(e.key_cols))]
            edges.append({
                "tables": sorted(key),
                "key_cols": list(e.key_cols),
                "pair": e.tables,
                "keys": keys_mat,
                "ids": {t: a.copy() for t, a in e.ids.items()},
            })

    tmp = os.path.join(ckpt_dir, f".tmp_ckpt_{lsn}")
    final = os.path.join(ckpt_dir, f"ckpt_{lsn}")
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)

    def put(name: str, arr: np.ndarray):
        np.save(os.path.join(tmp, name + ".npy"), arr)
        return name + ".npy"

    files: Dict[str, int] = {}
    man_tables = {}
    for t, dt in cols.items():
        man_tables[t] = {"capacity": caps[t], "columns": sorted(dt)}
        for c, v in dt.items():
            files[put(f"t.{t}.{c}", v)] = 0
        files[put(f"t.{t}.live", live[t])] = 0
    man_edges = []
    for i, e in enumerate(edges):
        spec = {"tables": e["tables"], "key_cols": e["key_cols"],
                "pair": list(e["pair"]),
                "n_keys": 0 if e["keys"] is None else len(e["keys"][0])}
        if e["keys"] is not None:
            for j, kcol in enumerate(e["keys"]):
                files[put(f"e{i}.key{j}", kcol)] = 0
        for t, a in e["ids"].items():
            files[put(f"e{i}.ids.{t}", a)] = 0
        man_edges.append(spec)
    for name in files:
        files[name] = _crc(os.path.join(tmp, name))
    manifest = {"format": _FORMAT, "lsn": lsn, "jt_version": jtv,
                "tables": man_tables, "edges": man_edges, "files": files,
                "t_wall": time.time()}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    _fault_call(fault, "ckpt.before_rename", lsn=lsn)
    shutil.rmtree(final, ignore_errors=True)
    os.rename(tmp, final)
    _fault_call(fault, "ckpt.after_rename", lsn=lsn)
    latest_tmp = os.path.join(ckpt_dir, ".LATEST_tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(lsn))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _fault_call(fault, "ckpt.after", lsn=lsn)
    _gc(ckpt_dir, keep)
    get_registry().counter("recovery.checkpoints").inc()
    return final


def _gc(ckpt_dir: str, keep: int):
    lsns = sorted(_all_lsns(ckpt_dir))
    for l in lsns[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"ckpt_{l}"),
                      ignore_errors=True)


def _all_lsns(ckpt_dir: str) -> List[int]:
    try:
        names = os.listdir(ckpt_dir)
    except OSError:
        return []
    return [int(d.split("_", 1)[1]) for d in names
            if d.startswith("ckpt_") and d.split("_", 1)[1].isdigit()]


def latest_checkpoint_lsn(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    try:
        with open(p) as f:
            return int(f.read().strip())
    except (OSError, ValueError):
        return None


# ------------------------------------------------------------------- load --
def _load_one(schema: Schema, d: str) -> Tuple[DynamicState, int]:
    """Load one checkpoint dir (raises on any validation failure)."""
    with open(os.path.join(d, "manifest.json")) as f:
        man = json.load(f)
    if man.get("format") != _FORMAT:
        raise RecoveryError(f"{d}: unknown checkpoint format {man.get('format')}")
    for name, crc in man["files"].items():
        p = os.path.join(d, name)
        if _crc(p) != crc:
            raise RecoveryError(f"{d}/{name}: checksum mismatch (bit rot)")

    def get(name: str) -> np.ndarray:
        return np.load(os.path.join(d, name + ".npy"))

    state = DynamicState.__new__(DynamicState)
    state.schema = schema
    state.tables = {}
    for t in schema.tables:
        spec = man["tables"][t.name]
        dt = DynamicTable.__new__(DynamicTable)
        dt.name = t.name
        dt.feature_columns = tuple(t.feature_columns)
        dt.capacity = spec["capacity"]
        dt.columns = {c: get(f"t.{t.name}.{c}") for c in spec["columns"]}
        dt.live = get(f"t.{t.name}.live").astype(bool)
        state.tables[t.name] = dt
    state.edges = {}
    for i, spec in enumerate(man["edges"]):
        e = DynamicEdge.__new__(DynamicEdge)
        e.key_cols = tuple(spec["key_cols"])
        e.tables = tuple(spec["pair"])
        e.ids = {t: get(f"e{i}.ids.{t}").astype(np.int32)
                 for t in spec["tables"]}
        e.key_to_id = {}
        if spec["n_keys"]:
            kcols = [get(f"e{i}.key{j}")
                     for j in range(len(spec["key_cols"]))]
            for kid, key in enumerate(zip(*kcols)):
                e.key_to_id[tuple(key)] = kid
        state.edges[frozenset(spec["tables"])] = e
    state.data_version = man["lsn"]
    state.jt_version = man["jt_version"]
    state._jts = {}
    state._jt_built_at = {}
    state._listeners = []
    state.wal = None
    import threading
    state.lock = threading.RLock()
    return state, man["lsn"]


def load_checkpoint(schema: Schema, ckpt_dir: str
                    ) -> Tuple[Optional[DynamicState], int, int]:
    """Newest VALID checkpoint → ``(state | None, lsn, skipped)``.

    Tries the ``LATEST`` pointer first, then every checkpoint dir
    newest-first; a checkpoint that fails validation (missing file, CRC
    mismatch, truncated manifest) is skipped — recovery falls back to
    an older one and replays a longer WAL tail instead.
    """
    candidates = sorted(set(_all_lsns(ckpt_dir)), reverse=True)
    latest = latest_checkpoint_lsn(ckpt_dir)
    if latest in candidates:                 # pointer first, then the rest
        candidates.remove(latest)
        candidates.insert(0, latest)
    skipped = 0
    for lsn in candidates:
        d = os.path.join(ckpt_dir, f"ckpt_{lsn}")
        try:
            state, at = _load_one(schema, d)
            return state, at, skipped
        except Exception:
            skipped += 1
    return None, 0, skipped


# ---------------------------------------------------------------- recover --
def _replay_tail(apply_fn, current_lsn: int, wal_dir: str
                 ) -> Tuple[int, int, int]:
    """Replay WAL records with lsn > current_lsn through ``apply_fn``.
    Returns (final_lsn, n_replayed, tail_bytes_discarded)."""
    path = wal_path(wal_dir)
    if not os.path.exists(path):
        return current_lsn, 0, 0
    size = os.path.getsize(path)
    if size < len(MAGIC):                    # crash at log creation
        return current_lsn, 0, size
    lsn = current_lsn
    n = 0
    end = len(MAGIC)
    for rec_lsn, deltas, _, off in read_records(path):
        end = off
        if rec_lsn == 0 or rec_lsn <= current_lsn:
            continue                         # heartbeat / pre-checkpoint
        if rec_lsn != lsn + 1:
            raise RecoveryError(
                f"WAL gap: checkpoint at {current_lsn}, replay reached "
                f"{lsn}, next record is {rec_lsn}")
        apply_fn(deltas)
        lsn = rec_lsn
        n += 1
    return lsn, n, max(0, size - end)


def recover_state(schema: Schema, wal_dir: str,
                  ckpt_dir: Optional[str] = None
                  ) -> Tuple[DynamicState, RecoveryReport]:
    """Newest valid checkpoint + WAL tail replay → a live state at the
    last durable LSN.  A torn/corrupt tail record is discarded (its
    version never committed durably); mid-log corruption raises
    :class:`~repro.incremental.wal.WalCorruptError`."""
    t0 = time.perf_counter()
    state = None
    ckpt_lsn = 0
    skipped = 0
    if ckpt_dir is not None:
        state, ckpt_lsn, skipped = load_checkpoint(schema, ckpt_dir)
    if state is None:
        state = DynamicState(schema)
        ckpt_lsn = 0
    final, n, discarded = _replay_tail(state.apply, ckpt_lsn, wal_dir)
    rep = RecoveryReport(
        checkpoint_lsn=ckpt_lsn, recovered_lsn=final, replayed=n,
        tail_bytes_discarded=discarded, checkpoints_skipped=skipped,
        replay_s=time.perf_counter() - t0,
    )
    _note_metrics(rep)
    return state, rep


def recover_scorer(ens, wal_dir: str, ckpt_dir: Optional[str] = None,
                   **scorer_kw) -> Tuple["MaintainedScorer", RecoveryReport]:
    """Recover into a fresh serving view: a
    :class:`~repro.incremental.maintain.MaintainedScorer` over ``ens``
    (compiled on the BASE schema — the t=0 schema the log started
    from), its dynamic state replaced by the recovered one, stacked
    leaf-mask factor rows re-evaluated for every recovered live slot
    (bit-identical to having maintained them all along — factor rows
    are pure per-row functions of current column values), and the WAL
    tail replayed through ``scorer.apply`` so factors track the replay.
    """
    from .maintain import MaintainedScorer

    t0 = time.perf_counter()
    ms = MaintainedScorer(ens, **scorer_kw)
    state = None
    ckpt_lsn = 0
    skipped = 0
    if ckpt_dir is not None:
        state, ckpt_lsn, skipped = load_checkpoint(ens.schema, ckpt_dir)
    if state is not None:
        ms.adopt_state(state)
    final, n, discarded = _replay_tail(ms.apply, ckpt_lsn, wal_dir)
    rep = RecoveryReport(
        checkpoint_lsn=ckpt_lsn, recovered_lsn=final, replayed=n,
        tail_bytes_discarded=discarded, checkpoints_skipped=skipped,
        replay_s=time.perf_counter() - t0,
    )
    _note_metrics(rep)
    return ms, rep


def _note_metrics(rep: RecoveryReport):
    reg = get_registry()
    reg.counter("recovery.runs").inc()
    reg.counter("recovery.replayed_records").inc(rep.replayed)
    reg.counter("recovery.tail_bytes_discarded").inc(rep.tail_bytes_discarded)
    reg.counter("recovery.checkpoints_skipped").inc(rep.checkpoints_skipped)
    reg.gauge("recovery.recovered_lsn").set(rep.recovered_lsn)
    reg.histogram("recovery.replay_ms").observe(rep.replay_s * 1e3)
