"""Typed table deltas + the dynamic (capacity-padded) relational state.

Incremental view maintenance (Kara et al.'s static/dynamic split) needs
three things the static :class:`~repro.core.schema.Schema` does not
provide: a mutable row store, a stable row-id space under churn, and
join-key dictionaries that grow as unseen keys arrive.  This module
provides them host-side:

- :class:`TableDelta` — one batch of inserts / deletes / updates against
  one table (the unit ``MaintainedScorer.apply`` consumes).
- :class:`DynamicTable` — a capacity-padded column store with a liveness
  mask.  Deletes mark slots dead (their factor rows become the semiring
  ⊕-identity, so they drop out of every join); inserts fill the lowest
  free slots and double capacity when none remain.  Row ids ARE slots:
  they never shift, so memoized grouped scores stay aligned across
  deltas.
- :class:`DynamicEdge` — an insertion-ordered dense key dictionary for
  one undirected join-tree edge.  Existing key ids are never renumbered
  (messages stay cacheable); unseen key tuples append, and a key present
  on only one side simply ⊕-contributes to a segment nobody gathers —
  exactly natural-join semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.schema import Table


@dataclasses.dataclass
class TableDelta:
    """One batch of row changes against one table.

    inserts: column → (k,) values; every column of the table required.
    deletes: (k,) slot ids (must be live).
    updates: (slots, {column → (k,) values}) — non-key columns only; a
    join-key change is semantically delete + insert and must be issued
    as such (it moves the row between join groups).
    """

    table: str
    inserts: Optional[Dict[str, np.ndarray]] = None
    deletes: Optional[np.ndarray] = None
    updates: Optional[Tuple[np.ndarray, Dict[str, np.ndarray]]] = None

    @property
    def n_ops(self) -> int:
        n = 0
        if self.inserts:
            n += len(next(iter(self.inserts.values())))
        if self.deletes is not None:
            n += len(self.deletes)
        if self.updates is not None:
            n += len(self.updates[0])
        return n


class DynamicTable:
    """Capacity-padded mutable mirror of one :class:`Table`."""

    def __init__(self, table: Table, slack: float = 0.25):
        n = table.n_rows
        self.name = table.name
        self.feature_columns = tuple(table.feature_columns)
        self.capacity = n + max(1, int(np.ceil(slack * n)))
        self.columns: Dict[str, np.ndarray] = {}
        for c, v in table.columns.items():
            v = np.asarray(v)
            pad = np.zeros((self.capacity - n,), v.dtype)
            self.columns[c] = np.concatenate([v, pad])
        self.live = np.zeros((self.capacity,), bool)
        self.live[:n] = True

    @property
    def n_live(self) -> int:
        return int(self.live.sum())

    def live_slots(self) -> np.ndarray:
        return np.flatnonzero(self.live)

    def _grow(self, need: int):
        new_cap = max(2 * self.capacity, self.capacity + need)
        for c, v in self.columns.items():
            pad = np.zeros((new_cap - self.capacity,), v.dtype)
            self.columns[c] = np.concatenate([v, pad])
        self.live = np.concatenate(
            [self.live, np.zeros((new_cap - self.capacity,), bool)]
        )
        self.capacity = new_cap

    def apply(self, delta: TableDelta) -> Tuple[np.ndarray, bool]:
        """Apply one delta.  Returns (slots whose values changed — updates
        then inserts, in application order — and whether capacity grew).
        Deletes are reported via the (cleared) ``live`` mask."""
        if delta.table != self.name:
            raise ValueError(f"delta for {delta.table!r} applied to {self.name!r}")
        grew = False
        if delta.deletes is not None and len(delta.deletes):
            slots = np.unique(np.asarray(delta.deletes, np.int64))
            if slots.min() < 0 or slots.max() >= self.capacity or not self.live[slots].all():
                raise IndexError(f"delete of non-live slots in table {self.name!r}")
            self.live[slots] = False
        changed: List[np.ndarray] = []
        if delta.updates is not None:
            slots, cols = delta.updates
            slots = np.asarray(slots, np.int64)
            if len(slots):
                if slots.min() < 0 or slots.max() >= self.capacity or not self.live[slots].all():
                    raise IndexError(f"update of non-live slots in table {self.name!r}")
                for c, v in cols.items():
                    if c not in self.columns:
                        raise KeyError(f"table {self.name!r} has no column {c!r}")
                    self.columns[c][slots] = np.asarray(v, self.columns[c].dtype)
                changed.append(slots)
        if delta.inserts:
            missing = set(self.columns) - set(delta.inserts)
            if missing:
                raise KeyError(f"insert into {self.name!r} missing columns {sorted(missing)}")
            k = len(next(iter(delta.inserts.values())))
            free = np.flatnonzero(~self.live)
            if len(free) < k:
                self._grow(k - len(free))
                grew = True
                free = np.flatnonzero(~self.live)
            slots = free[:k]
            for c, v in delta.inserts.items():
                self.columns[c][slots] = np.asarray(v, self.columns[c].dtype)
            self.live[slots] = True
            changed.append(slots)
        out = (np.concatenate(changed) if changed
               else np.zeros((0,), np.int64))
        return out, grew

    def effective(self) -> Table:
        """The current logical table: live rows in slot order (the oracle
        a fresh compile is checked against, bit-for-bit)."""
        slots = self.live_slots()
        return Table(
            name=self.name,
            columns={c: v[slots].copy() for c, v in self.columns.items()},
            feature_columns=self.feature_columns,
        )


class DynamicEdge:
    """Maintained dense key dictionary for one undirected join edge.

    Ids are insertion-ordered and append-only: cached messages indexed by
    key id stay valid as the domain grows (new ids pad with ⊕-identity).
    Dead/never-filled slots carry id 0 — their factor rows are semiring
    zero, so they ⊕-contribute nothing to segment 0.
    """

    def __init__(self, a: DynamicTable, b: DynamicTable, key_cols: Sequence[str]):
        self.key_cols = tuple(key_cols)
        self.tables = (a.name, b.name)
        self.key_to_id: Dict[Tuple, int] = {}
        self.ids: Dict[str, np.ndarray] = {
            t.name: np.zeros((t.capacity,), np.int32) for t in (a, b)
        }
        for t in (a, b):
            self.assign(t, t.live_slots())

    @property
    def n_keys(self) -> int:
        return max(len(self.key_to_id), 1)

    def _keys_at(self, table: DynamicTable, slots: np.ndarray) -> np.ndarray:
        return np.stack([table.columns[c][slots] for c in self.key_cols], axis=1)

    def assign(self, table: DynamicTable, slots: np.ndarray) -> bool:
        """(Re)assign key ids for ``slots`` of ``table``; returns whether
        the key domain grew (cached messages then need ⊕-identity pads)."""
        if table.name not in self.ids:
            raise KeyError(f"table {table.name!r} not on edge {self.tables}")
        ids = self.ids[table.name]
        if len(ids) < table.capacity:                    # capacity grew
            pad = np.zeros((table.capacity - len(ids),), np.int32)
            self.ids[table.name] = ids = np.concatenate([ids, pad])
        before = len(self.key_to_id)
        if len(slots):
            for s, key in zip(slots, map(tuple, self._keys_at(table, slots))):
                ids[s] = self.key_to_id.setdefault(key, len(self.key_to_id))
        return len(self.key_to_id) > before
