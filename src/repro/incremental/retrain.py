"""Incremental relational boosting: maintained messages feed RETRAINING.

The boosting loop is dominated by grouped sum-of-squared-residual
queries; PR 2 maintains exactly those aggregates incrementally for
*serving*.  Following "The Relational Data Borg is Learning", this
module closes the loop back into training:

- :class:`MaintainedEngine` is a :class:`~repro.core.engine.QueryEngine`
  that answers the Booster's node-statistics queries (fused c3 channels,
  leaf-pair counts, polynomial sketches) from a signature-keyed per-edge
  message cache (:class:`~repro.core.sumprod.MessageCache`) over a
  :class:`~repro.incremental.state.DynamicState` kept fresh under
  :class:`TableDelta` streams.  Per query family it hashes each table's
  concrete row mask (node-uniform tables collapse to one broadcast row),
  and re-emits a segment-⊕ only on edges whose child subtree's
  signatures miss the cache — unchanged-subtree messages are reused
  across tree levels, across trees, and across deltas, so a delta-epoch
  of boosting queries emits strictly fewer edges than the per-query
  inside-out baseline (benchmarks/bench_retrain.py audits the ratio).

- :class:`IncrementalBooster` wraps a :class:`Booster` bound to that
  engine: ``apply(deltas)`` mutates the store and invalidates exactly
  the changed tables' bases/signatures; ``refit(deltas, n_new_trees)``
  warm-starts — it measures residual drift with a cheap sketched SSR
  query, and only when drift exceeds the threshold appends (or, over a
  tree budget, replaces the most recent) trees fitted on the residuals
  of the frozen prefix.

- Split-plan maintenance is delta-driven too: the engine accumulates
  touched slots from its state subscription and serves them through
  :meth:`MaintainedEngine.plan_delta`, so in histogram split mode
  (``BoostConfig.split_mode="hist"``) each ``refresh_plans`` re-bins
  only delta rows against frozen quantile edges (``core/hist.py``)
  instead of re-argsorting every table.

Why the engine is host-orchestrated (``jittable = False``): cache keys
hash concrete mask bytes, which a traced level step cannot provide.
Costs stay honest — every real segment-⊕ emission bumps
``QueryCounter.edges`` (the direct engine's analytic accounting is the
baseline), and tree-shape work stays batched over nodes.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..obs import get_registry, span
from ..distributed import spmd
from ..core.engine import QueryEngine
from ..core.schema import Schema
from ..core.semiring import Arithmetic, PolyFreq
from ..core.sketch import monomial_coeff, monomial_freq
from ..core.sumprod import MessageCache, QueryCounter, SumProd
from ..core.trainer import BoostConfig, Booster, FitTrace
from ..core.tree import TreeArrays
from .deltas import TableDelta
from .state import DynamicState, TableChange


class MaintainedEngine(QueryEngine):
    """Grouped boosting queries answered from maintained messages.

    Sharding: captures the ambient `spmd` data mesh at construction.
    The capacity-shaped query bases (`_c3_base`, `_cnt_base`, sketch
    monomials, feature matrices) are placed row-sharded on rebuild —
    the engine is eager (host-orchestrated), so device placement sticks
    without in-graph constraints — and the memoized message pass's
    emissions are the collective point.  Grouped outputs replicate at
    the engine boundary, so signatures, cache keys and the split sweep
    are identical to single-device.
    """

    jittable = False          # signatures hash concrete mask bytes
    analytic_edges = False    # every real emission is counted here

    def __init__(self, state: DynamicState,
                 counter: Optional[QueryCounter] = None,
                 max_cache_per_edge: int = 64):
        self.state = state
        self.counter = counter
        self.mesh = spmd.current_data_mesh()
        self.cache = MessageCache(max_per_edge=max_cache_per_edge)
        self._version: Dict[str, int] = {n: 0 for n in state.tables}
        self._stale = set(state.tables)
        # slots whose feature values (or liveness) changed since the last
        # plan_delta() consumption — the o(n) feed for hist-plan rebinning
        self._plan_dirty: Dict[str, List[np.ndarray]] = {}
        # every state.apply — whoever issues it — flows through notify,
        # so a shared DynamicState can never leave this engine stale
        state.subscribe(self.notify)
        # maintained projection dictionaries (the schema's static w_ids,
        # made append-only so sketch hashes stay stable under churn)
        self._proj: Dict[str, Dict[tuple, int]] = {n: {} for n in state.tables}
        self._w_ids: Dict[str, np.ndarray] = {}

    # ---------------------------------------------------------------- bind --
    def bind(self, booster) -> None:
        self.booster = booster
        schema: Schema = booster.schema
        self.schema = schema
        if self.counter is None:
            self.counter = booster.counter
        self.sp = SumProd(schema, counter=self.counter)
        self.c3 = booster.c3
        self.sem = booster.sem
        self.hashes = booster.hashes
        self._ar = Arithmetic()
        self._owned = {
            t.name: [c for c in t.columns if schema.owner[c] == t.name]
            for t in schema.tables
        }
        for name, dt in self.state.tables.items():
            self._w_ids[name] = np.zeros((dt.capacity,), np.int64)
            self._assign_proj(name, dt.live_slots())
        self._live: Dict[str, jnp.ndarray] = {}
        self._featmat: Dict[str, jnp.ndarray] = {}
        self._c3_base: Dict[str, jnp.ndarray] = {}
        self._cnt_base: Dict[str, jnp.ndarray] = {}
        self._sk_base: Dict[str, jnp.ndarray] = {}
        self._sk_label: Dict[str, jnp.ndarray] = {}
        self.refresh()

    # -------------------------------------------------------------- deltas --
    def _assign_proj(self, table: str, slots: np.ndarray):
        """Append-only projection ids for ``slots`` (changed/inserted
        rows): an unseen projection tuple gets the next id — existing
        rows keep theirs, so their sketch monomials (and any cached
        message built from them) stay valid."""
        dt = self.state.tables[table]
        ids = self._w_ids[table]
        if len(ids) < dt.capacity:                     # capacity grew
            ids = np.concatenate(
                [ids, np.zeros((dt.capacity - len(ids),), np.int64)]
            )
            self._w_ids[table] = ids
        owned = self._owned.get(table)
        if not owned or not len(slots):
            return
        proj = self._proj[table]
        cols = [dt.columns[c] for c in owned]
        for s in np.asarray(slots, np.int64):
            key = tuple(c[s] for c in cols)
            ids[s] = proj.setdefault(key, len(proj))

    def notify(self, changes: Sequence[TableChange]):
        """Invalidate per-table bases/signatures for applied deltas
        (subscribed to ``DynamicState.apply``).  Bumping ``_version`` is
        what retires cached messages: any edge whose child subtree
        contains the table can no longer hit."""
        for ch in changes:
            if len(ch.changed) or len(ch.deleted) or ch.grew:
                self._version[ch.table] += 1
                self._stale.add(ch.table)
                touched = np.concatenate([ch.changed, ch.deleted])
                if len(touched):
                    self._plan_dirty.setdefault(ch.table, []).append(touched)
                # pre-bind deltas need no projection upkeep: bind()
                # assigns ids for every live slot from scratch
                if hasattr(self, "_owned"):
                    self._assign_proj(ch.table, ch.changed)

    def refresh(self):
        """Rebuild the query bases of stale tables (no-op when clean).
        Holds the state lock: the rebuild reads live bits / feature
        columns that a concurrent ``state.apply`` mutates in place, and
        a torn base would poison the signature-keyed message cache."""
        if not self._stale:
            return
        with self.state.lock, span("engine.refresh", tables=len(self._stale)):
            for name in sorted(self._stale):
                self._rebuild(name)
            self._stale.clear()

    def _rebuild(self, name: str):
        schema, dt = self.schema, self.state.tables[name]
        cap = dt.capacity
        live_np = dt.live.copy()
        live = jnp.asarray(live_np)
        self._live[name] = live
        cols = schema.feat_cols[name]
        if cols:
            fm = np.stack(
                [dt.columns[c][:cap].astype(np.float32) for c in cols], axis=1
            )
        else:
            fm = np.zeros((cap, 0), np.float32)
        self._featmat[name] = spmd.shard_rows(jnp.asarray(fm), self.mesh)
        ones = live.astype(jnp.float32)
        self._cnt_base[name] = spmd.shard_rows(ones, self.mesh)
        if name == schema.label_table:
            lbl_np = dt.columns[schema.label_column][:cap].astype(np.float32)
            lbl_np = np.where(live_np, lbl_np, 0.0)
            lbl = jnp.asarray(lbl_np)
            self._c3_base[name] = spmd.shard_rows(
                jnp.stack([ones, lbl, jnp.square(lbl)], -1), self.mesh)
        else:
            lbl = None
            self._c3_base[name] = spmd.shard_rows(
                self.c3.mask(self.c3.ones((cap,)), live), self.mesh)
        h = self.hashes.hashes[name]
        w = jnp.asarray(self._w_ids[name][:cap])
        mono = monomial_freq if isinstance(self.sem, PolyFreq) else monomial_coeff
        m = self.sem.mask(mono(self.sem, h.sign(w), h.bucket(w)), live)
        self._sk_base[name] = spmd.shard_rows(m, self.mesh)
        self._sk_label[name] = (spmd.shard_rows(self.sem.scale(m, lbl), self.mesh)
                                if lbl is not None else self._sk_base[name])

    # ------------------------------------------------------------- queries --
    def _combine(self, name: str, mask, extra):
        """Canonical (K, capacity) keep mask: node masks ∧ optional leaf
        mask ∧ liveness (dead slots' garbage feature bits must not leak
        into signatures)."""
        m = mask & self._live[name][None, :]
        if extra is not None:
            m = m & extra[None, :]
        return m

    def _grouped(self, kinds, bases, sem, table, keeps):
        """One grouped query family: per-table signatures → memoized
        message pass → root combine.  Node-uniform tables collapse to a
        single broadcast row, making their signatures (and cached
        messages) independent of the level's node count K.  ``kinds``:
        base-identity tag per table (str applies to every table)."""
        with self.state.lock:
            # materialize under the lock (jt() splices mutable numpy key
            # ids into immutable jnp arrays); the pass below then runs on
            # frozen bases/trees only
            jt = self.state.jt(table)
        K = next(iter(keeps.values())).shape[0]
        factors, sigs = {}, {}
        with span("engine.grouped", table=table,
                  kind=kinds if isinstance(kinds, str) else "sk"), \
                spmd.use_data_mesh(self.mesh):
            for name, keep in keeps.items():
                k_np = np.asarray(keep)
                uniform = K == 1 or bool((k_np == k_np[:1]).all())
                rows = k_np[:1] if uniform else k_np
                digest = hashlib.blake2b(rows.tobytes(), digest_size=12).digest()
                kind = kinds if isinstance(kinds, str) else kinds[name]
                sigs[name] = (kind, self._version[name], rows.shape[0], digest)
                factors[name] = sem.mask(bases[name][None], jnp.asarray(rows))
            msgs = self.sp.messages_memo(sem, factors, jt, sigs, self.cache)
            # replicate at the engine boundary: the split sweep downstream
            # must see the same bits/layout as single-device
            out = spmd.replicate(
                self.sp.node_factor(sem, factors, jt, jt.root, msgs),
                self.mesh)
        if out.shape[0] != K:
            out = jnp.broadcast_to(out, (K,) + out.shape[1:])
        return out

    def grouped_c3(self, table, masks, extra=None):
        self.refresh()
        keeps = {
            tn: self._combine(tn, masks[tn],
                              None if extra is None else extra[tn])
            for tn in masks
        }
        return self._grouped("c3", self._c3_base, self.c3, table, keeps)

    def grouped_count_pair(self, table, masks, extra_a, extra_b):
        self.refresh()
        keeps = {
            tn: self._combine(tn, masks[tn] & extra_a[tn][None, :],
                              extra_b[tn])
            for tn in masks
        }
        return self._grouped("cnt", self._cnt_base, self._ar, table, keeps)

    def grouped_sketch(self, table, masks, extra=None, labeled=False):
        self.refresh()
        keeps = {
            tn: self._combine(tn, masks[tn],
                              None if extra is None else extra[tn])
            for tn in masks
        }
        bases = self._sk_label if labeled else self._sk_base
        # the labeled/unlabeled bases differ only at the label table —
        # sharing the kind tag everywhere else lets their subtree
        # messages interchange
        kinds = {tn: (("skl" if labeled else "sku")
                      if tn == self.schema.label_table else "sk")
                 for tn in keeps}
        return self._grouped(kinds, bases, self.sem, table, keeps)

    # -------------------------------------------------------- data surface --
    def n_rows(self, table):
        return self.state.capacity(table)

    def mask_featmat(self, table):
        self.refresh()
        return self._featmat[table]

    def plan_featmats(self):
        return {name: self.plan_featmat(name) for name in self.state.tables}

    def plan_featmat(self, table):
        self.refresh()
        fm = np.asarray(self._featmat[table]).copy()
        fm[~self.state.tables[table].live] = np.inf    # dead slots can't
        return fm                                      # become thresholds

    def plan_delta(self):
        """Slots touched since the last consumption, with their CURRENT
        feature values straight from the dynamic store (multiple deltas
        to one slot collapse; deleted slots read +inf) — O(|delta|·d_t)
        host work, never a full-table scan.  Deltas applied before the
        booster bound (and built full plans) may linger here; re-binning
        them is idempotent."""
        with self.state.lock:
            dirty, self._plan_dirty = self._plan_dirty, {}
            out = {}
            for name, chunks in dirty.items():
                slots = np.unique(np.concatenate(chunks))
                out[name] = (slots, self.state.feature_rows(name, slots))
            return out


@dataclasses.dataclass
class RefitReport:
    """What one :meth:`IncrementalBooster.refit` call did and cost."""

    refitted: bool
    drift: float                 # relative residual (MSE) growth since last fit
    mse_before: float
    mse_after: float
    n_new: int                   # trees fitted this call
    n_trees: int                 # ensemble size after the call
    queries: int                 # SumProd queries this call
    edges: int                   # real segment-⊕ emissions this call
    cache_hit_rate: float        # message-cache hit rate (lifetime)


class IncrementalBooster:
    """Delta-driven warm-start retraining on maintained messages."""

    def __init__(self, schema: Schema, cfg: BoostConfig, key=None,
                 slack: float = 0.25,
                 counter: Optional[QueryCounter] = None,
                 max_cache_per_edge: int = 64):
        self.schema = schema
        self.cfg = cfg
        self.state = DynamicState(schema, slack=slack)
        self.engine = MaintainedEngine(self.state, counter=counter,
                                       max_cache_per_edge=max_cache_per_edge)
        self.mesh = self.engine.mesh          # ambient spmd mesh, if any
        self.booster = Booster(schema, cfg, key=key, engine=self.engine)
        # one counter for everything: analytic query counts from the
        # trainer, real edge emissions from the engine
        self.counter = self.engine.counter
        self.booster.counter = self.counter
        self.trees: List[TreeArrays] = []
        self.trace = FitTrace()
        self._mse_ref: Optional[float] = None
        # wall-clock instant of the oldest delta the model has not yet
        # been (re)evaluated against — the training-side freshness lag
        self._stale_since: Optional[float] = None

    # -------------------------------------------------------------- deltas --
    def apply(self, deltas: Sequence[TableDelta]) -> int:
        """Mutate the store; the engine invalidates via its state
        subscription, and bases/plans refresh lazily at next query."""
        if isinstance(deltas, TableDelta):
            deltas = [deltas]
        with span("retrain.apply", n_deltas=len(deltas)):
            self.state.apply(deltas)
        if self._stale_since is None:
            self._stale_since = time.perf_counter()
        get_registry().counter("retrain.deltas").inc(len(deltas))
        return self.state.data_version

    def staleness_s(self, root: Optional[str] = None) -> float:
        """Seconds the model has been behind applied deltas (0.0 once a
        refit/drift check has consumed them).  ``root`` is accepted for
        surface-compatibility with :class:`MaintainedScorer` (the
        serving batcher passes its group-by root) — model freshness
        here is global, so it is ignored."""
        if self._stale_since is None:
            return 0.0
        return max(0.0, time.perf_counter() - self._stale_since)

    def compile_snapshot(self):
        """Publish the current ensemble as a static
        :class:`~repro.serving.compile.CompiledEnsemble` pinned at the
        store's ``data_version`` — an immutable scoring artifact over
        the live rows at this instant, safe to hand to a
        :class:`~repro.serving.service.ModelRegistry` while training
        continues to mutate the shared state.  Captured under the state
        lock so the effective schema and the version agree."""
        from ..serving.compile import compile_ensemble
        with self.state.lock:
            eff = self.state.effective_schema()
            dv = self.state.data_version
        ens = compile_ensemble(eff, self.trees)
        ens.data_version = dv
        return ens

    def _mark_fresh(self) -> None:
        """Model state re-evaluated against every applied delta: record
        the consumed lag and reset the staleness clock."""
        if self._stale_since is not None:
            reg = get_registry()
            reg.histogram("retrain.delta_lag_s").observe(
                time.perf_counter() - self._stale_since)
            reg.gauge("retrain.staleness_s").set(0.0)
            self._stale_since = None

    def live_rows(self, table: str) -> np.ndarray:
        return self.state.live_rows(table)

    def effective_schema(self) -> Schema:
        return self.state.effective_schema()

    # ----------------------------------------------------------- residuals --
    def _leaf_state(self):
        per_tree = [self.booster._leaf_masks(t) for t in self.trees]
        prev_masks = {
            t.name: jnp.concatenate([pm[t.name] for pm in per_tree])
            for t in self.schema.tables
        } if per_tree else {}
        prev_vals = (jnp.concatenate([t.leaf for t in self.trees])
                     if self.trees else jnp.zeros((0,), jnp.float32))
        return prev_masks, prev_vals

    def ensemble_mse(self) -> float:
        """Mean squared residual of the CURRENT ensemble over the live
        join — one sketched-SSR query family per frozen leaf, all served
        from the message cache (repeat calls on unchanged data emit no
        edges).  Sketched ⇒ (1±ε)-accurate, exactly the paper's Thm 3.4
        guarantee; used as the refit drift signal."""
        self.engine.refresh()
        lbl = self.schema.label_table
        masks = {
            t.name: jnp.ones((1, self.state.capacity(t.name)), jnp.bool_)
            for t in self.schema.tables
        }
        c3 = self.booster._grouped_c3(lbl, masks)          # (1, cap, 3)
        n = float(jnp.sum(c3[..., 0]))
        uy = float(jnp.sum(c3[..., 2]))
        if not self.trees:
            return uy / max(n, 1.0)
        sem = self.booster.sem
        resid = self.booster._grouped_sketch(lbl, masks, labeled=True)
        prev_masks, prev_vals = self._leaf_state()
        for a in range(int(prev_vals.shape[0])):
            extra = {tn: prev_masks[tn][a] for tn in prev_masks}
            s = self.booster._grouped_sketch(lbl, masks, extra=extra)
            resid = resid - sem.scale(s, jnp.zeros(()) + prev_vals[a])
        ssr = float(jnp.sum(sem.norm_sq(resid)))
        return max(ssr, 0.0) / max(n, 1.0)

    # ------------------------------------------------------------- fitting --
    def fit(self) -> Tuple[List[TreeArrays], FitTrace]:
        """From-scratch fit through the maintained engine."""
        self.engine.refresh()
        self.booster.refresh_plans()
        self.trees, self.trace = self.booster.boost([], self.cfg.n_trees)
        self._mse_ref = self.ensemble_mse()
        self._mark_fresh()
        return self.trees, self.trace

    def refit(
        self,
        deltas: Optional[Sequence[TableDelta]] = None,
        n_new_trees: int = 1,
        drift_threshold: float = 0.0,
        max_trees: Optional[int] = None,
    ) -> RefitReport:
        """Apply ``deltas`` (if any) and warm-start on the result.

        Residual drift = relative MSE growth of the current ensemble on
        the live data since the last (re)fit.  At or below
        ``drift_threshold`` the model is left alone (the maintained
        aggregates absorbed the delta); above it, ``n_new_trees`` trees
        are fitted on the frozen ensemble's residuals.  With a
        ``max_trees`` budget, the most recent trees are dropped first to
        make room — they encode the finest residual structure, which the
        delta invalidated."""
        reg = get_registry()
        t0 = time.perf_counter()
        if deltas is not None:
            self.apply(deltas)
        self.engine.refresh()
        self.booster.refresh_plans()
        c = self.counter
        q0, e0 = c.count, c.edges
        with span("retrain.drift_check"):
            mse0 = self.ensemble_mse()
        # the drift check re-evaluated the ensemble on post-delta data —
        # whatever the verdict, the model is no longer behind the store
        self._mark_fresh()
        drift = (float("inf") if self._mse_ref is None
                 else (mse0 - self._mse_ref) / max(self._mse_ref, 1e-12))
        reg.gauge("retrain.drift").set(0.0 if drift == float("inf") else drift)
        if self.trees and drift <= drift_threshold:
            reg.counter("retrain.kept").inc()
            return RefitReport(
                refitted=False, drift=drift, mse_before=mse0, mse_after=mse0,
                n_new=0, n_trees=len(self.trees),
                queries=c.count - q0, edges=c.edges - e0,
                cache_hit_rate=self.engine.cache.hit_rate,
            )
        if max_trees is not None:
            keep = max(0, max_trees - n_new_trees)
            self.trees = self.trees[:keep]
        with span("retrain.refit", n_new=n_new_trees, drift=round(drift, 4)
                  if drift != float("inf") else None):
            self.trees, self.trace = self.booster.boost(self.trees, n_new_trees)
        mse1 = self.ensemble_mse()
        self._mse_ref = mse1
        reg.counter("retrain.refits").inc()
        reg.histogram("retrain.refit_ms").observe((time.perf_counter() - t0) * 1e3)
        reg.histogram("retrain.refit_edges").observe(c.edges - e0)
        return RefitReport(
            refitted=True, drift=drift, mse_before=mse0, mse_after=mse1,
            n_new=n_new_trees, n_trees=len(self.trees),
            queries=c.count - q0, edges=c.edges - e0,
            cache_hit_rate=self.engine.cache.hit_rate,
        )
