"""Incremental view maintenance for serving AND training.

    TableDelta                    — typed insert/delete/update batch
    DynamicTable / DynamicEdge    — capacity-padded mutable store + keys
    DynamicState / TableChange    — shared mutable schema mirror
    MaintainedScorer              — delta-driven factors, path-restricted
                                    (jitted) message refresh, versioned memo
    MaintainedEngine              — boosting queries from cached messages
    IncrementalBooster            — delta-driven warm-start retraining
"""
from .deltas import DynamicEdge, DynamicTable, TableDelta
from .state import DynamicState, TableChange
from .maintain import MaintainedScorer
from .retrain import IncrementalBooster, MaintainedEngine, RefitReport

__all__ = [
    "DynamicEdge", "DynamicTable", "TableDelta",
    "DynamicState", "TableChange",
    "MaintainedScorer",
    "IncrementalBooster", "MaintainedEngine", "RefitReport",
]
