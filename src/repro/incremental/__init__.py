"""Incremental view maintenance for serving AND training.

    TableDelta                    — typed insert/delete/update batch
    DynamicTable / DynamicEdge    — capacity-padded mutable store + keys
    DynamicState / TableChange    — shared mutable schema mirror
    StateView                     — immutable pin of one state version
    MaintainedScorer              — delta-driven factors, path-restricted
                                    (jitted) message refresh, versioned memo
    Snapshot                      — MVCC view pinned at one data_version
    MaintainedEngine              — boosting queries from cached messages
    IncrementalBooster            — delta-driven warm-start retraining
    WalWriter / WalReader         — crash-consistent delta log (LSN =
                                    data_version, group-committed fsyncs)
    WalFollower                   — tail a writer's log into a replica
    save_checkpoint / recover_*   — atomic checkpoints + tail replay
"""
from .deltas import DynamicEdge, DynamicTable, TableDelta
from .state import DynamicState, StateView, TableChange
from .maintain import MaintainedScorer, Snapshot
from .retrain import IncrementalBooster, MaintainedEngine, RefitReport
from .wal import WalCorruptError, WalFollower, WalReader, WalWriter
from .recover import (
    RecoveryReport, recover_scorer, recover_state, save_checkpoint,
)

__all__ = [
    "DynamicEdge", "DynamicTable", "TableDelta",
    "DynamicState", "StateView", "TableChange",
    "MaintainedScorer", "Snapshot",
    "IncrementalBooster", "MaintainedEngine", "RefitReport",
    "WalCorruptError", "WalFollower", "WalReader", "WalWriter",
    "RecoveryReport", "recover_scorer", "recover_state", "save_checkpoint",
]
