"""Incremental view maintenance for the relational serving subsystem.

    TableDelta                    — typed insert/delete/update batch
    DynamicTable / DynamicEdge    — capacity-padded mutable store + keys
    MaintainedScorer              — delta-driven factors, path-restricted
                                    message refresh, versioned memo
"""
from .deltas import DynamicEdge, DynamicTable, TableDelta
from .maintain import MaintainedScorer

__all__ = ["DynamicEdge", "DynamicTable", "TableDelta", "MaintainedScorer"]
