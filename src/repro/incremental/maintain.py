"""Delta-driven maintenance of compiled ensembles and memoized scores.

:class:`MaintainedScorer` turns the one-shot :class:`CompiledEnsemble`
into a continuously maintainable view (the static/dynamic factorization
of Kara et al.): typed table deltas update (a) the per-table stacked
leaf-mask factors — only the changed rows' mask slices are re-evaluated
and scattered in — and (b) the memoized grouped counts/scores, by
re-emitting segment-⊕ messages only along the changed tables' paths to
the root and ⊗-combining them with the cached clean messages
(:meth:`SumProd.refresh_messages`).  A full inside-out recompute costs
one segment-⊕ per join-tree edge; a single-table delta costs one per
edge on that table's root path — O(depth) instead of O(τ−1).

The scorer duck-types the slice of :class:`CompiledEnsemble` the serving
layer uses (``factors`` / ``leaf_values`` / ``grouped_cached`` /
``n_rows``), so it can be published to a :class:`ModelRegistry` and
served by the micro-batcher unchanged; every applied delta bumps
``data_version``, which the service folds into its result-cache key so
stale scores are unreachable.  Row ids are slots in the capacity-padded
store: live rows keep their ids across deltas, dead slots score as
(0, 0) — count 0 marks "row not in the join", same as a live row whose
key matches nothing.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.schema import JoinTree, Schema, Table, TreeEdge
from ..core.sumprod import QueryCounter, SumProd
from ..serving.compile import CompiledEnsemble, compile_ensemble, stack_table_factor
from .deltas import DynamicEdge, DynamicTable, TableDelta


class MaintainedScorer:
    """A compiled ensemble plus the dynamic state that keeps it fresh."""

    def __init__(self, ens: CompiledEnsemble, slack: float = 0.25,
                 counter: Optional[QueryCounter] = None):
        sch = ens.schema
        self.schema = sch
        self.source = ens
        self.trees = ens.trees
        self.leaf_values = ens.leaf_values
        self.tree0_leaves = ens.tree0_leaves
        self.total_leaves = ens.total_leaves
        self.counter = counter if counter is not None else ens.counter
        self._sem = ens._sem
        self._sp = SumProd(sch, counter=self.counter)
        self.factor_dtype = ens.factor_dtype
        self.data_version = 0

        self.tables: Dict[str, DynamicTable] = {
            t.name: DynamicTable(t, slack=slack) for t in sch.tables
        }
        # one maintained key dictionary per undirected join edge
        self.edges: Dict[frozenset, DynamicEdge] = {}
        for a, b, key in sch._undirected_edges:
            self.edges[frozenset((a, b))] = DynamicEdge(
                self.tables[a], self.tables[b], key
            )

        # capacity-padded factors: source rows verbatim, dead slots ⊕-zero
        self.factors: Dict[str, jnp.ndarray] = {}
        for t in sch.tables:
            dt = self.tables[t.name]
            pad = dt.capacity - t.n_rows
            self.factors[t.name] = jnp.concatenate([
                ens.factors[t.name],
                jnp.zeros((pad, self.total_leaves), self.factor_dtype),
            ])

        # jitted per-table delta-row mask evaluation (compile-once per
        # (table, delta-rows) shape — the apply() hot path)
        self._mask_fns: Dict[str, callable] = {}

        # per-root cached state (created lazily on first score)
        self._jts: Dict[str, JoinTree] = {}
        self._jt_version = 0                     # bumps on any id/key change
        self._jt_built_at: Dict[str, int] = {}
        self._msgs: Dict[str, List[jnp.ndarray]] = {}
        self._dirty: Dict[str, Set[int]] = {}
        self._grouped: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]] = {}

    # ------------------------------------------------------------- queries --
    def n_rows(self, table: str) -> int:
        return self.tables[table].capacity

    def live_rows(self, table: str) -> np.ndarray:
        return self.tables[table].live_slots()

    def effective_schema(self) -> Schema:
        """A fresh static Schema over the live rows (slot order) — the
        full-recompute oracle the maintained scores must match."""
        return Schema(
            [self.tables[t.name].effective() for t in self.schema.tables],
            label=(self.schema.label_table, self.schema.label_column),
        )

    def _jt(self, root: str) -> JoinTree:
        """Join tree for ``root`` with the MAINTAINED key-id arrays spliced
        into the schema's static edge order."""
        if self._jt_built_at.get(root) == self._jt_version and root in self._jts:
            return self._jts[root]
        base = self.schema.join_tree(root)
        names = self.schema.names
        edges = []
        for e in base.edges:
            de = self.edges[frozenset((names[e.child], names[e.parent]))]
            edges.append(TreeEdge(
                child=e.child, parent=e.parent, key_cols=e.key_cols,
                child_ids=jnp.asarray(de.ids[names[e.child]], jnp.int32),
                parent_ids=jnp.asarray(de.ids[names[e.parent]], jnp.int32),
                n_keys=de.n_keys,
            ))
        jt = JoinTree(root=base.root, edges=tuple(edges))
        self._jts[root] = jt
        self._jt_built_at[root] = self._jt_version
        return jt

    # -------------------------------------------------------------- deltas --
    def apply(self, deltas: Sequence[TableDelta]) -> int:
        """Apply a delta batch; returns the new ``data_version``.

        Per table: mutate the dynamic store, re-evaluate leaf-mask factor
        rows for just the changed slots, refresh incident key ids for
        inserts, and mark the table dirty in every cached root's message
        state.  Nothing global is recomputed here — the path-restricted
        refresh happens lazily at the next score."""
        if isinstance(deltas, TableDelta):
            deltas = [deltas]
        structural = False
        for d in deltas:
            if d.table not in self.tables:
                raise KeyError(f"unknown table {d.table!r}")
            dt = self.tables[d.table]
            if d.updates is not None:
                key_cols = {c for e in self.edges.values()
                            if d.table in e.tables for c in e.key_cols}
                bad = key_cols & set(d.updates[1])
                if bad:
                    raise ValueError(
                        f"update of join-key columns {sorted(bad)} on "
                        f"{d.table!r}: issue delete + insert instead"
                    )
            had_deletes = d.deletes is not None and len(d.deletes) > 0
            n_ins = (len(next(iter(d.inserts.values()))) if d.inserts else 0)
            changed, grew = dt.apply(d)

            if grew:
                structural = True
                cur = self.factors[d.table]
                self.factors[d.table] = jnp.concatenate([
                    cur,
                    jnp.zeros((dt.capacity - cur.shape[0], cur.shape[1]),
                              cur.dtype),
                ])
            # inserts (tail of `changed`) need key ids on incident edges;
            # key-domain growth is absorbed by refresh_messages' ⊕-identity
            # padding, so only the id arrays (→ join trees) go stale here
            if n_ins:
                structural = True
                ins_slots = changed[-n_ins:]
                for e in self.edges.values():
                    if d.table in e.tables:
                        e.assign(dt, ins_slots)
            # zero deleted slots BEFORE scattering fresh rows: an insert in
            # this same delta may have reused a just-deleted slot
            if had_deletes:
                gone = jnp.asarray(np.unique(np.asarray(d.deletes, np.int64)),
                                   jnp.int32)
                self.factors[d.table] = self.factors[d.table].at[gone].set(0)
            if len(changed):
                self._refresh_factor_rows(d.table, changed)
            if len(changed) or had_deletes:
                ti = self.schema.index[d.table]
                for root in self._msgs:
                    self._dirty.setdefault(root, set()).add(ti)
        if structural:
            self._jt_version += 1
        self._grouped.clear()
        self.data_version += 1
        return self.data_version

    def _refresh_factor_rows(self, table: str, slots: np.ndarray):
        """Re-evaluate the stacked leaf masks for ``slots`` and scatter
        them into the live factor (elementwise per-row ops — identical
        bits to a full-table recompute of the same rows)."""
        dt = self.tables[table]
        cols = self.schema.feat_cols[table]
        k = len(slots)
        if cols:
            rows = np.stack(
                [dt.columns[c][slots].astype(np.float32) for c in cols], axis=1
            )
        else:
            rows = np.zeros((k, 0), np.float32)
        sl = jnp.asarray(slots, jnp.int32)
        if table not in self._mask_fns:
            sch, trees, dt_ = self.schema, self.trees, self.factor_dtype

            def masks(featmat, table=table):
                return stack_table_factor(sch, trees, table,
                                          featmat=featmat, dtype=dt_)

            self._mask_fns[table] = jax.jit(masks)
        # bucket the delta size to the next power of two so arbitrary
        # stream shapes hit at most log(k) jit compilations per table
        k_pad = 1 << (max(k, 1) - 1).bit_length()
        if k_pad > k:
            rows = np.concatenate(
                [rows, np.zeros((k_pad - k, rows.shape[1]), np.float32)]
            )
        frows = self._mask_fns[table](jnp.asarray(rows))
        self.factors[table] = self.factors[table].at[sl].set(frows[:k])

    # ------------------------------------------------------------- scoring --
    def _counts(self, group_by: str) -> jnp.ndarray:
        """Grouped leaf counts via cached messages + path refresh."""
        jt = self._jt(group_by)
        sem, sp = self._sem, self._sp
        dirty = self._dirty.get(group_by)
        if group_by not in self._msgs:
            self._msgs[group_by] = sp.messages(sem, self.factors, jt=jt)
        elif dirty:
            self._msgs[group_by] = sp.refresh_messages(
                sem, self.factors, self._msgs[group_by], dirty, jt
            )
        self._dirty[group_by] = set()
        return sp.node_factor(sem, self.factors, jt, jt.root, self._msgs[group_by])

    def score_grouped(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(Σŷ, |ρ⋈J|) per slot of ``group_by`` — maintained counts, same
        contraction as the compiled scorer.  Dead slots read (0, 0)."""
        if self.counter is not None:
            self.counter.bump(1)
        counts = self._counts(group_by)
        tot = (counts @ self.leaf_values).astype(jnp.float32)
        cnt = jnp.sum(counts[:, :self.tree0_leaves], axis=1).astype(jnp.float32)
        return tot, cnt

    def grouped_cached(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if group_by not in self._grouped:
            self._grouped[group_by] = self.score_grouped(group_by)
        return self._grouped[group_by]

    def recompute_oracle(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Ground-truth full recompute: a fresh static compile over the
        effective live tables (new key dictionaries, no cached state),
        evaluated through an eager message pass.  Returned arrays are
        capacity-shaped (live slots filled, dead slots 0) so they compare
        bit-for-bit against the maintained grouped output: the leaf
        counts are integer-exact either way, and routing the final
        contraction through the same-shape matvec removes the one
        remaining float-reassociation freedom (XLA's gemv blocks rows
        differently for different n, which would otherwise perturb a few
        ulps).  A jitted ``compile_ensemble(...).score_grouped`` agrees
        to allclose, not bitwise — its fused matvec reassociates."""
        eff = self.effective_schema()
        fresh = compile_ensemble(eff, self.trees, factor_dtype=self.factor_dtype)
        sp = SumProd(eff)
        jt = eff.join_tree(group_by)
        msgs = sp.messages(fresh._sem, fresh.factors, jt=jt)
        counts = sp.node_factor(fresh._sem, fresh.factors, jt, jt.root, msgs)
        full = jnp.zeros(
            (self.tables[group_by].capacity, counts.shape[1]), counts.dtype
        ).at[jnp.asarray(self.live_rows(group_by), jnp.int32)].set(counts)
        tot = (full @ fresh.leaf_values).astype(jnp.float32)
        cnt = jnp.sum(full[:, :fresh.tree0_leaves], axis=1).astype(jnp.float32)
        return tot, cnt

    def score_full(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full-recompute reference over the SAME maintained state (every
        edge re-emitted) — the benchmark baseline for the edge-count and
        latency ratios.  Does not touch the cached messages."""
        jt = self._jt(group_by)
        msgs = self._sp.messages(self._sem, self.factors, jt=jt)
        counts = self._sp.node_factor(self._sem, self.factors, jt, jt.root, msgs)
        tot = (counts @ self.leaf_values).astype(jnp.float32)
        cnt = jnp.sum(counts[:, :self.tree0_leaves], axis=1).astype(jnp.float32)
        return tot, cnt
