"""Delta-driven maintenance of compiled ensembles and memoized scores.

:class:`MaintainedScorer` turns the one-shot :class:`CompiledEnsemble`
into a continuously maintainable view (the static/dynamic factorization
of Kara et al.): typed table deltas update (a) the per-table stacked
leaf-mask factors — only the changed rows' mask slices are re-evaluated
and scattered in — and (b) the memoized grouped counts/scores, by
re-emitting segment-⊕ messages only along the changed tables' paths to
the root and ⊗-combining them with the cached clean messages.  A full
inside-out recompute costs one segment-⊕ per join-tree edge; a
single-table delta costs one per edge on that table's root path —
O(depth) instead of O(τ−1).

The mutable substrate (capacity-padded stores, append-only key
dictionaries, maintained join trees) lives in
:class:`~repro.incremental.state.DynamicState`, shared with the
incremental retraining engine (retrain.py); this module owns only the
serving-specific state: stacked leaf-mask factors and message caches.

The path-restricted refresh itself is JITTED: one compiled program per
(root, dirty-set signature, shape fingerprint), re-emitting exactly the
edges :func:`~repro.core.sumprod.refresh_plan` marks.  The emission
count is bumped eagerly from the same plan, so ``QueryCounter.edges``
accounting is identical to the eager :meth:`SumProd.refresh_messages`
route — the IVM benchmarks' ratios are compile-cache independent.

The scorer duck-types the slice of :class:`CompiledEnsemble` the serving
layer uses (``factors`` / ``leaf_values`` / ``grouped_cached`` /
``n_rows``), so it can be published to a :class:`ModelRegistry` and
served by the micro-batcher unchanged; every applied delta bumps
``data_version``, which the service folds into its result-cache key so
stale scores are unreachable.  Row ids are slots in the capacity-padded
store: live rows keep their ids across deltas, dead slots score as
(0, 0) — count 0 marks "row not in the join", same as a live row whose
key matches nothing.

For CONCURRENT ingest + serve the scorer publishes MVCC
:class:`Snapshot` views (:meth:`MaintainedScorer.snapshot`): an
immutable pin of factors + cached messages + join trees at one
``data_version``, captured under ``state.lock`` and served lock-free
while ``apply`` builds the next version.  Torn reads are impossible by
construction — a snapshot never aliases mutable state — and refreshed
messages flow back to the live scorer when versions still agree, so
the isolation is free of duplicate message emissions.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Set, Tuple

import time

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import get_registry, span
from ..core.schema import Schema
from ..core.sumprod import QueryCounter, SumProd, refresh_plan
from ..distributed import spmd
from ..serving.compile import CompiledEnsemble, compile_ensemble, stack_table_factor
from .deltas import DynamicEdge, DynamicTable, TableDelta
from .state import DynamicState, StateView


class MaintainedScorer:
    """A compiled ensemble plus the dynamic state that keeps it fresh.

    Sharding: inherits the source ensemble's data mesh (or the ambient
    `spmd` context).  Capacity-padded factors are placed row-sharded
    when the capacity divides the data axis (capacities are slack-padded
    and growth-doubled, so tables fall back to replicated whenever they
    don't — correct either way under the divisibility drop rule);
    message (re-)emission inside the cached/jitted refresh is the
    collective point, and grouped counts are replicated before the final
    contraction so served scores are bit-equal to single-device.
    """

    def __init__(self, ens: CompiledEnsemble, slack: float = 0.25,
                 counter: Optional[QueryCounter] = None,
                 served_window_s: float = 30.0,
                 snapshot_retention: int = 4):
        sch = ens.schema
        self.schema = sch
        self.source = ens
        self.trees = ens.trees
        self.leaf_values = ens.leaf_values
        self.tree0_leaves = ens.tree0_leaves
        self.total_leaves = ens.total_leaves
        self.counter = counter if counter is not None else ens.counter
        self._sem = ens._sem
        self._sp = SumProd(sch, counter=self.counter)
        self.factor_dtype = ens.factor_dtype
        self.data_version = 0
        self.mesh = ens.mesh if ens.mesh is not None else spmd.current_data_mesh()

        self.state = DynamicState(sch, slack=slack)
        self.tables: Dict[str, DynamicTable] = self.state.tables
        self.edges: Dict[frozenset, DynamicEdge] = self.state.edges

        # capacity-padded factors: source rows verbatim, dead slots ⊕-zero
        self.factors: Dict[str, jnp.ndarray] = {}
        for t in sch.tables:
            dt = self.tables[t.name]
            pad = dt.capacity - t.n_rows
            self.factors[t.name] = spmd.shard_factor(jnp.concatenate([
                ens.factors[t.name],
                jnp.zeros((pad, self.total_leaves), self.factor_dtype),
            ]), self.mesh)
        self.leaf_values = spmd.replicate_put(self.leaf_values, self.mesh)

        # jitted per-table delta-row mask evaluation (compile-once per
        # (table, delta-rows) shape — the apply() hot path)
        self._mask_fns: Dict[str, callable] = {}
        # jitted path-restricted refresh programs, keyed by
        # (root, dirty-set, jt version, message/factor shapes)
        self._refresh_fns: Dict[tuple, tuple] = {}

        # per-root cached state (created lazily on first score)
        self._msgs: Dict[str, List[jnp.ndarray]] = {}
        self._dirty: Dict[str, Set[int]] = {}
        self._grouped: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]] = {}
        # wall-clock instant of the oldest applied-but-unrefreshed delta,
        # PER ROOT (absent = that root's served view is fully caught up)
        # — the data-staleness signal the SLO monitor burns against.  A
        # root only counts toward the aggregate gauge while it is being
        # served (queried within `served_window_s`): a root abandoned by
        # traffic must not pin the staleness objective forever.
        self._stale_since: Dict[str, float] = {}
        self._last_query: Dict[str, float] = {}
        self.served_window_s = served_window_s
        # recently published MVCC snapshots, keyed by data_version.  The
        # cache retains at most `snapshot_retention` versions (GC on
        # every apply/publish): evicted snapshots keep serving for
        # whoever still references them — the scorer just stops pinning
        # their factors/messages against collection.  The gauges let
        # /metricsz watch pin pressure (a long-pinned old version shows
        # up as oldest_pin_age_s growing without bound).
        self.snapshot_retention = max(1, int(snapshot_retention))
        self._snaps: Dict[int, "Snapshot"] = {}

    # ------------------------------------------------------------- queries --
    def n_rows(self, table: str) -> int:
        return self.tables[table].capacity

    def live_rows(self, table: str) -> np.ndarray:
        return self.state.live_rows(table)

    def effective_schema(self) -> Schema:
        """A fresh static Schema over the live rows (slot order) — the
        full-recompute oracle the maintained scores must match."""
        return self.state.effective_schema()

    # -------------------------------------------------------------- deltas --
    def apply(self, deltas: Sequence[TableDelta]) -> int:
        """Apply a delta batch; returns the new ``data_version``.

        Per table: mutate the dynamic store (via ``DynamicState``),
        re-evaluate leaf-mask factor rows for just the changed slots, and
        mark the table dirty in every cached root's message state.
        Nothing global is recomputed here — the path-restricted refresh
        happens lazily at the next score."""
        if isinstance(deltas, TableDelta):
            deltas = [deltas]
        t0 = time.perf_counter()
        # the state lock makes the whole batch one atomic version step:
        # a concurrent snapshot() observes either none or all of it, and
        # never a factor scatter without its data_version bump
        with self.state.lock, span("ivm.apply", n_deltas=len(deltas)):
            for ch in self.state.apply(deltas):
                if ch.grew:
                    cur = self.factors[ch.table]
                    cap = self.tables[ch.table].capacity
                    # re-place after growth: the new capacity may (not)
                    # divide the data axis — shard_factor re-resolves
                    self.factors[ch.table] = spmd.shard_factor(jnp.concatenate([
                        cur,
                        jnp.zeros((cap - cur.shape[0], cur.shape[1]), cur.dtype),
                    ]), self.mesh)
                # zero deleted slots BEFORE scattering fresh rows: an insert in
                # this same delta may have reused a just-deleted slot
                if len(ch.deleted):
                    gone = jnp.asarray(ch.deleted, jnp.int32)
                    self.factors[ch.table] = self.factors[ch.table].at[gone].set(0)
                if len(ch.changed):
                    self._refresh_factor_rows(ch.table, ch.changed)
                if len(ch.changed) or len(ch.deleted):
                    ti = self.schema.index[ch.table]
                    now = time.perf_counter()
                    for root in self._msgs:
                        self._dirty.setdefault(root, set()).add(ti)
                        self._stale_since.setdefault(root, now)
            self._grouped.clear()
            self.data_version += 1
            self._gc_snapshots()
        reg = get_registry()
        reg.counter("ivm.deltas").inc(len(deltas))
        reg.histogram("ivm.apply_ms").observe((time.perf_counter() - t0) * 1e3)
        return self.data_version

    def staleness_s(self, root: Optional[str] = None) -> float:
        """Wall-clock lag of the served view behind applied deltas.

        With ``root``: 0.0 when that root's cached messages reflect the
        current ``data_version``, else seconds since its oldest
        unrefreshed delta landed.  Without: the max over *served* roots
        — those queried within ``served_window_s`` — so a root traffic
        has abandoned cannot pin the gauge (and trip the SLO staleness
        objective) forever.  Before any root has been queried, all
        stale roots count.  The serving batcher mirrors its group-by
        root's reading into the ``service.staleness_s`` gauge."""
        now = time.perf_counter()
        if root is not None:
            t = self._stale_since.get(root)
            return max(0.0, now - t) if t is not None else 0.0
        if not self._stale_since:
            return 0.0
        if self._last_query:
            candidates = [t for r, t in self._stale_since.items()
                          if now - self._last_query.get(r, -np.inf)
                          <= self.served_window_s]
        else:
            candidates = list(self._stale_since.values())
        if not candidates:
            return 0.0
        return max(0.0, now - min(candidates))

    def _note_fresh(self, root: str) -> None:
        """Record that ``root``'s served view just caught up: observe
        how long its resolved deltas sat unserved (the delta lag) and
        re-sample the aggregate staleness gauge."""
        t = self._stale_since.pop(root, None)
        reg = get_registry()
        if t is not None:
            reg.histogram("ivm.refresh_lag_s").observe(time.perf_counter() - t)
        reg.gauge("ivm.staleness_s").set(self.staleness_s())

    def _refresh_factor_rows(self, table: str, slots: np.ndarray):
        """Re-evaluate the stacked leaf masks for ``slots`` and scatter
        them into the live factor (elementwise per-row ops — identical
        bits to a full-table recompute of the same rows)."""
        dt = self.tables[table]
        cols = self.schema.feat_cols[table]
        k = len(slots)
        if cols:
            rows = np.stack(
                [dt.columns[c][slots].astype(np.float32) for c in cols], axis=1
            )
        else:
            rows = np.zeros((k, 0), np.float32)
        sl = jnp.asarray(slots, jnp.int32)
        if table not in self._mask_fns:
            sch, trees, dt_ = self.schema, self.trees, self.factor_dtype

            def masks(featmat, table=table):
                return stack_table_factor(sch, trees, table,
                                          featmat=featmat, dtype=dt_)

            self._mask_fns[table] = jax.jit(masks)
        # bucket the delta size to the next power of two so arbitrary
        # stream shapes hit at most log(k) jit compilations per table
        k_pad = 1 << (max(k, 1) - 1).bit_length()
        if k_pad > k:
            rows = np.concatenate(
                [rows, np.zeros((k_pad - k, rows.shape[1]), np.float32)]
            )
        frows = self._mask_fns[table](jnp.asarray(rows))
        self.factors[table] = self.factors[table].at[sl].set(frows[:k])

    # ------------------------------------------------------------- scoring --
    def _refresh_fn(self, root: str, dirty: frozenset, jt, msgs,
                    jt_version: int, factors):
        """Compiled path-restricted refresh for one (root, dirty-set,
        shape fingerprint); returns (jitted fn, #edges it re-emits).
        The plan is computed ONCE from :func:`refresh_plan` — the same
        source of truth the eager route uses — so the cached program
        re-emits exactly the edges the eager route would, and the edge
        accounting (bumped eagerly by the caller) cannot drift.
        ``jt``/``msgs``/``jt_version``/``factors`` are explicit so MVCC
        snapshots pinned at an older version share this compile cache:
        a snapshot's shapes fingerprint alongside the live scorer's."""
        fingerprint = (
            root, dirty, jt_version,
            tuple(m.shape for m in msgs),
            tuple((tn, factors[tn].shape) for tn in sorted(factors)),
        )
        hit = self._refresh_fns.get(fingerprint)
        if hit is not None:
            return hit
        sem, sp = self._sem, self._sp                # node_factor never bumps
        mesh = self.mesh
        plan = refresh_plan(jt, dirty)
        pads = [max(0, e.n_keys - msgs[i].shape[0])
                for i, e in enumerate(jt.edges)]

        def run(factors, msgs):
            new = list(msgs)
            for i, e in enumerate(jt.edges):
                if pads[i]:                          # key domain grew: ⊕-pad
                    new[i] = jnp.concatenate(
                        [new[i], sem.zeros((pads[i],))], axis=0
                    )
                if plan[i]:
                    cf = sp.node_factor(sem, factors, jt, e.child, new)
                    new[i] = spmd.psum_message(
                        sem.segment_add(cf, e.child_ids, e.n_keys), mesh)
            return new

        out = (jax.jit(run), sum(plan))
        if len(self._refresh_fns) > 128:             # bound compile cache
            self._refresh_fns.clear()
        self._refresh_fns[fingerprint] = out
        return out

    def _counts(self, group_by: str) -> jnp.ndarray:
        """Grouped leaf counts via cached messages + jitted path refresh."""
        jt = self.state.jt(group_by)
        sem, sp = self._sem, self._sp
        dirty = self._dirty.get(group_by)
        if group_by not in self._msgs:
            with spmd.use_data_mesh(self.mesh):
                self._msgs[group_by] = sp.messages(sem, self.factors, jt=jt)
        elif dirty:
            t0 = time.perf_counter()
            with span("ivm.refresh", root=group_by, dirty=len(dirty)):
                run, n_emit = self._refresh_fn(
                    group_by, frozenset(dirty), jt, self._msgs[group_by],
                    self.state.jt_version, self.factors)
                self._msgs[group_by] = run(self.factors, self._msgs[group_by])
            if self.counter is not None:
                self.counter.bump_edges(n_emit)
            get_registry().histogram("ivm.refresh_ms").observe(
                (time.perf_counter() - t0) * 1e3)
        self._dirty[group_by] = set()
        self._last_query[group_by] = time.perf_counter()
        self._note_fresh(group_by)
        # replicate before the serving contraction (see score_grouped)
        return spmd.replicate(
            sp.node_factor(sem, self.factors, jt, jt.root, self._msgs[group_by]),
            self.mesh)

    def score_grouped(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(Σŷ, |ρ⋈J|) per slot of ``group_by`` — maintained counts, same
        contraction as the compiled scorer.  Dead slots read (0, 0)."""
        if self.counter is not None:
            self.counter.bump(1)
        counts = self._counts(group_by)
        tot = (counts @ self.leaf_values).astype(jnp.float32)
        cnt = jnp.sum(counts[:, :self.tree0_leaves], axis=1).astype(jnp.float32)
        return tot, cnt

    def grouped_cached(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if group_by not in self._grouped:
            self._grouped[group_by] = self.score_grouped(group_by)
        return self._grouped[group_by]

    def recompute_oracle(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Ground-truth full recompute: a fresh static compile over the
        effective live tables (new key dictionaries, no cached state),
        evaluated through an eager message pass.  Returned arrays are
        capacity-shaped (live slots filled, dead slots 0) so they compare
        bit-for-bit against the maintained grouped output: the leaf
        counts are integer-exact either way, and routing the final
        contraction through the same-shape matvec removes the one
        remaining float-reassociation freedom (XLA's gemv blocks rows
        differently for different n, which would otherwise perturb a few
        ulps).  A jitted ``compile_ensemble(...).score_grouped`` agrees
        to allclose, not bitwise — its fused matvec reassociates."""
        with self.state.lock:
            eff = self.effective_schema()
            live = self.live_rows(group_by)
            cap = self.tables[group_by].capacity
        return self._oracle_from(eff, group_by, live, cap)

    def _oracle_from(self, eff: Schema, group_by: str, live, capacity: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """The recompute oracle over an EXPLICIT effective schema /
        live-slot / capacity pin — shared by :meth:`recompute_oracle`
        (current state) and :meth:`Snapshot.recompute_oracle` (a frozen
        historical version)."""
        # the oracle is pinned single-device (use_data_mesh(None) clears
        # any ambient mesh): ground truth must not depend on sharding
        with spmd.use_data_mesh(None):
            fresh = compile_ensemble(eff, self.trees,
                                     factor_dtype=self.factor_dtype)
            sp = SumProd(eff)
            jt = eff.join_tree(group_by)
            msgs = sp.messages(fresh._sem, fresh.factors, jt=jt)
            counts = sp.node_factor(fresh._sem, fresh.factors, jt, jt.root, msgs)
        full = jnp.zeros(
            (capacity, counts.shape[1]), counts.dtype
        ).at[jnp.asarray(live, jnp.int32)].set(counts)
        tot = (full @ fresh.leaf_values).astype(jnp.float32)
        cnt = jnp.sum(full[:, :fresh.tree0_leaves], axis=1).astype(jnp.float32)
        return tot, cnt

    # ----------------------------------------------------------- snapshots --
    def snapshot(self, roots: Optional[Sequence[str]] = None,
                 pin_oracle: bool = False) -> "Snapshot":
        """Publish an immutable MVCC :class:`Snapshot` of the current
        ``data_version``.

        Cheap: jax arrays are immutable (``apply`` rebinds new arrays,
        never writes through old ones), so the factor dict and cached
        message lists are captured by reference; the only real work is
        join-tree materialization, cached per ``jt_version``.  The
        result is cached until the next ``apply``, so concurrent
        batches at one version share one snapshot.

        ``roots`` limits which roots the snapshot can serve (default:
        every table); ``pin_oracle=True`` additionally freezes the
        effective schema + live slots so :meth:`Snapshot.recompute_oracle`
        stays bit-exact after the live state has moved on.
        """
        names = (tuple(sorted(roots)) if roots is not None
                 else tuple(t.name for t in self.schema.tables))
        with self.state.lock:
            snap = self._snaps.get(self.data_version)
            if (snap is not None
                    and set(names) <= set(snap.view.jts)
                    and (not pin_oracle or snap.view.schema is not None)):
                return snap
            view = self.state.snapshot(names, pin_oracle=pin_oracle)
            snap = Snapshot(
                owner=self, view=view, data_version=self.data_version,
                factors=dict(self.factors), leaf_values=self.leaf_values,
                msgs={r: list(self._msgs[r]) for r in names
                      if r in self._msgs},
                dirty={r: frozenset(self._dirty.get(r, ())) for r in names},
            )
            self._snaps[self.data_version] = snap
            self._gc_snapshots()
            return snap

    def _gc_snapshots(self) -> None:
        """Evict cached snapshot versions beyond the retention window
        and republish the pin-pressure gauges.  Called under
        ``state.lock`` (from ``apply`` and ``snapshot``)."""
        floor = self.data_version - self.snapshot_retention
        for v in [v for v in self._snaps if v <= floor]:
            del self._snaps[v]
        reg = get_registry()
        reg.gauge("snapshot.pinned_versions").set(len(self._snaps))
        oldest = min((s.t_created for s in self._snaps.values()),
                     default=None)
        reg.gauge("snapshot.oldest_pin_age_s").set(
            0.0 if oldest is None else max(0.0, time.time() - oldest))

    def adopt_state(self, state: DynamicState) -> None:
        """Replace the dynamic substrate with a RECOVERED state (a
        checkpoint load — see :mod:`repro.incremental.recover`).

        The stacked leaf-mask factors are re-evaluated for every live
        slot of the adopted state; factor rows are pure per-row
        functions of current column values, so the result is
        bit-identical to having maintained them through the original
        delta stream.  All cached messages, memoized scores, staleness
        markers and snapshots are dropped (they referred to the old
        substrate), and ``data_version`` adopts the recovered LSN."""
        with state.lock:
            self.state = state
            self.tables = state.tables
            self.edges = state.edges
            self.factors = {}
            for t in self.schema.tables:
                dt = self.tables[t.name]
                self.factors[t.name] = spmd.shard_factor(
                    jnp.zeros((dt.capacity, self.total_leaves),
                              self.factor_dtype), self.mesh)
                live = dt.live_slots()
                if len(live):
                    self._refresh_factor_rows(t.name, live)
            self._msgs.clear()
            self._dirty.clear()
            self._grouped.clear()
            self._stale_since.clear()
            self._last_query.clear()
            self._snaps.clear()
            self.data_version = state.data_version

    def _absorb(self, root: str, data_version: int, msgs) -> None:
        """Adopt a snapshot's refreshed messages iff the live scorer is
        still at the snapshot's ``data_version`` — at the same version
        the snapshot and the live scorer share one dirty set (both only
        change under ``state.lock``), so its refresh IS the live
        refresh: serving through snapshots stays exactly as incremental
        as serving the scorer directly.  After the version has moved
        on, the refresh only served that snapshot; drop it."""
        with self.state.lock:
            if self.data_version != data_version:
                return
            self._msgs[root] = list(msgs)
            self._dirty[root] = set()
            self._last_query[root] = time.perf_counter()
            self._note_fresh(root)

    def score_full(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full-recompute reference over the SAME maintained state (every
        edge re-emitted) — the benchmark baseline for the edge-count and
        latency ratios.  Does not touch the cached messages."""
        jt = self.state.jt(group_by)
        with spmd.use_data_mesh(self.mesh):
            msgs = self._sp.messages(self._sem, self.factors, jt=jt)
        counts = spmd.replicate(
            self._sp.node_factor(self._sem, self.factors, jt, jt.root, msgs),
            self.mesh)
        tot = (counts @ self.leaf_values).astype(jnp.float32)
        cnt = jnp.sum(counts[:, :self.tree0_leaves], axis=1).astype(jnp.float32)
        return tot, cnt


class Snapshot:
    """An immutable MVCC view of a :class:`MaintainedScorer`, pinned at
    one ``data_version``.

    Duck-types the serving surface (``n_rows`` / ``score_grouped`` /
    ``grouped_cached`` / ``data_version`` / ``mesh``), so the
    micro-batcher dispatches against it unchanged while the owner
    applies the next version concurrently — reads never observe a
    half-applied delta because everything here is frozen: the factor
    dict and message lists were captured under ``state.lock`` and jax
    arrays are immutable, the join trees were materialized to jnp at
    capture.

    Snapshots are *lazily consistent*: one captured with pending dirty
    tables resolves them on first score through the owner's jitted
    path-refresh compile cache (same :func:`refresh_plan`, same edge
    accounting), then writes the refreshed messages back to the owner
    iff it is still at this version (:meth:`MaintainedScorer._absorb`)
    — so snapshot serving costs no extra message emissions over serving
    the live scorer.  Scoring a root outside the pinned set raises
    ``KeyError``.
    """

    def __init__(self, owner: MaintainedScorer, view: StateView,
                 data_version: int, factors, leaf_values, msgs, dirty):
        self._owner = owner
        self.view = view
        self.data_version = data_version
        self.t_created = time.time()
        self.jt_version = view.jt_version
        self.factors = factors
        self.leaf_values = leaf_values
        self.mesh = owner.mesh
        self._msgs = msgs           # root → message list (None until scored)
        self._dirty = dirty         # root → frozenset of dirty table idx
        self._grouped: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]] = {}
        # serializes lazy refresh within ONE snapshot; never held while
        # taking state.lock (write-back happens after release)
        self._lock = threading.Lock()

    # ------------------------------------------------------------- surface --
    def roots(self) -> Tuple[str, ...]:
        return tuple(sorted(self.view.jts))

    def n_rows(self, table: str) -> int:
        return self.view.capacities[table]

    def _counts(self, group_by: str) -> jnp.ndarray:
        jt = self.view.jt(group_by)              # KeyError if not pinned
        o = self._owner
        sem, sp = o._sem, o._sp
        with self._lock:
            msgs = self._msgs.get(group_by)
            dirty = self._dirty.get(group_by, frozenset())
            if msgs is None:
                with spmd.use_data_mesh(self.mesh):
                    msgs = sp.messages(sem, self.factors, jt=jt)
            elif dirty:
                t0 = time.perf_counter()
                with span("ivm.refresh", root=group_by, dirty=len(dirty)):
                    run, n_emit = o._refresh_fn(
                        group_by, dirty, jt, msgs, self.jt_version,
                        self.factors)
                    msgs = run(self.factors, msgs)
                if o.counter is not None:
                    o.counter.bump_edges(n_emit)
                get_registry().histogram("ivm.refresh_ms").observe(
                    (time.perf_counter() - t0) * 1e3)
            self._msgs[group_by] = msgs
            self._dirty[group_by] = frozenset()
        o._absorb(group_by, self.data_version, msgs)
        return spmd.replicate(
            sp.node_factor(sem, self.factors, jt, jt.root, msgs), self.mesh)

    def score_grouped(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(Σŷ, |ρ⋈J|) per slot at this snapshot's pinned version —
        identical contraction (and bits) to the owner at this version."""
        o = self._owner
        if o.counter is not None:
            o.counter.bump(1)
        counts = self._counts(group_by)
        tot = (counts @ self.leaf_values).astype(jnp.float32)
        cnt = jnp.sum(counts[:, :o.tree0_leaves], axis=1).astype(jnp.float32)
        return tot, cnt

    def grouped_cached(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        with self._lock:
            hit = self._grouped.get(group_by)
        if hit is None:
            hit = self.score_grouped(group_by)
            with self._lock:
                hit = self._grouped.setdefault(group_by, hit)
        return hit

    def recompute_oracle(self, group_by: str
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Ground-truth full recompute AT THIS PINNED VERSION — works
        even after the live state has moved on.  Requires the snapshot
        to have been taken with ``pin_oracle=True``."""
        if self.view.schema is None:
            raise ValueError(
                "snapshot was not captured with pin_oracle=True; "
                "no frozen effective schema to recompute from")
        return self._owner._oracle_from(
            self.view.schema, group_by,
            self.view.live[group_by], self.view.capacities[group_by])
