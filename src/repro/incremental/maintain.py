"""Delta-driven maintenance of compiled ensembles and memoized scores.

:class:`MaintainedScorer` turns the one-shot :class:`CompiledEnsemble`
into a continuously maintainable view (the static/dynamic factorization
of Kara et al.): typed table deltas update (a) the per-table stacked
leaf-mask factors — only the changed rows' mask slices are re-evaluated
and scattered in — and (b) the memoized grouped counts/scores, by
re-emitting segment-⊕ messages only along the changed tables' paths to
the root and ⊗-combining them with the cached clean messages.  A full
inside-out recompute costs one segment-⊕ per join-tree edge; a
single-table delta costs one per edge on that table's root path —
O(depth) instead of O(τ−1).

The mutable substrate (capacity-padded stores, append-only key
dictionaries, maintained join trees) lives in
:class:`~repro.incremental.state.DynamicState`, shared with the
incremental retraining engine (retrain.py); this module owns only the
serving-specific state: stacked leaf-mask factors and message caches.

The path-restricted refresh itself is JITTED: one compiled program per
(root, dirty-set signature, shape fingerprint), re-emitting exactly the
edges :func:`~repro.core.sumprod.refresh_plan` marks.  The emission
count is bumped eagerly from the same plan, so ``QueryCounter.edges``
accounting is identical to the eager :meth:`SumProd.refresh_messages`
route — the IVM benchmarks' ratios are compile-cache independent.

The scorer duck-types the slice of :class:`CompiledEnsemble` the serving
layer uses (``factors`` / ``leaf_values`` / ``grouped_cached`` /
``n_rows``), so it can be published to a :class:`ModelRegistry` and
served by the micro-batcher unchanged; every applied delta bumps
``data_version``, which the service folds into its result-cache key so
stale scores are unreachable.  Row ids are slots in the capacity-padded
store: live rows keep their ids across deltas, dead slots score as
(0, 0) — count 0 marks "row not in the join", same as a live row whose
key matches nothing.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

import time

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import get_registry, span
from ..core.schema import Schema
from ..core.sumprod import QueryCounter, SumProd, refresh_plan
from ..distributed import spmd
from ..serving.compile import CompiledEnsemble, compile_ensemble, stack_table_factor
from .deltas import DynamicEdge, DynamicTable, TableDelta
from .state import DynamicState


class MaintainedScorer:
    """A compiled ensemble plus the dynamic state that keeps it fresh.

    Sharding: inherits the source ensemble's data mesh (or the ambient
    `spmd` context).  Capacity-padded factors are placed row-sharded
    when the capacity divides the data axis (capacities are slack-padded
    and growth-doubled, so tables fall back to replicated whenever they
    don't — correct either way under the divisibility drop rule);
    message (re-)emission inside the cached/jitted refresh is the
    collective point, and grouped counts are replicated before the final
    contraction so served scores are bit-equal to single-device.
    """

    def __init__(self, ens: CompiledEnsemble, slack: float = 0.25,
                 counter: Optional[QueryCounter] = None):
        sch = ens.schema
        self.schema = sch
        self.source = ens
        self.trees = ens.trees
        self.leaf_values = ens.leaf_values
        self.tree0_leaves = ens.tree0_leaves
        self.total_leaves = ens.total_leaves
        self.counter = counter if counter is not None else ens.counter
        self._sem = ens._sem
        self._sp = SumProd(sch, counter=self.counter)
        self.factor_dtype = ens.factor_dtype
        self.data_version = 0
        self.mesh = ens.mesh if ens.mesh is not None else spmd.current_data_mesh()

        self.state = DynamicState(sch, slack=slack)
        self.tables: Dict[str, DynamicTable] = self.state.tables
        self.edges: Dict[frozenset, DynamicEdge] = self.state.edges

        # capacity-padded factors: source rows verbatim, dead slots ⊕-zero
        self.factors: Dict[str, jnp.ndarray] = {}
        for t in sch.tables:
            dt = self.tables[t.name]
            pad = dt.capacity - t.n_rows
            self.factors[t.name] = spmd.shard_factor(jnp.concatenate([
                ens.factors[t.name],
                jnp.zeros((pad, self.total_leaves), self.factor_dtype),
            ]), self.mesh)
        self.leaf_values = spmd.replicate_put(self.leaf_values, self.mesh)

        # jitted per-table delta-row mask evaluation (compile-once per
        # (table, delta-rows) shape — the apply() hot path)
        self._mask_fns: Dict[str, callable] = {}
        # jitted path-restricted refresh programs, keyed by
        # (root, dirty-set, jt version, message/factor shapes)
        self._refresh_fns: Dict[tuple, tuple] = {}

        # per-root cached state (created lazily on first score)
        self._msgs: Dict[str, List[jnp.ndarray]] = {}
        self._dirty: Dict[str, Set[int]] = {}
        self._grouped: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]] = {}
        # wall-clock instant of the oldest applied-but-unrefreshed delta
        # (None = the served view is fully caught up) — the data-staleness
        # signal the SLO monitor burns against
        self._stale_since: Optional[float] = None

    # ------------------------------------------------------------- queries --
    def n_rows(self, table: str) -> int:
        return self.tables[table].capacity

    def live_rows(self, table: str) -> np.ndarray:
        return self.state.live_rows(table)

    def effective_schema(self) -> Schema:
        """A fresh static Schema over the live rows (slot order) — the
        full-recompute oracle the maintained scores must match."""
        return self.state.effective_schema()

    # -------------------------------------------------------------- deltas --
    def apply(self, deltas: Sequence[TableDelta]) -> int:
        """Apply a delta batch; returns the new ``data_version``.

        Per table: mutate the dynamic store (via ``DynamicState``),
        re-evaluate leaf-mask factor rows for just the changed slots, and
        mark the table dirty in every cached root's message state.
        Nothing global is recomputed here — the path-restricted refresh
        happens lazily at the next score."""
        if isinstance(deltas, TableDelta):
            deltas = [deltas]
        t0 = time.perf_counter()
        with span("ivm.apply", n_deltas=len(deltas)):
            for ch in self.state.apply(deltas):
                if ch.grew:
                    cur = self.factors[ch.table]
                    cap = self.tables[ch.table].capacity
                    # re-place after growth: the new capacity may (not)
                    # divide the data axis — shard_factor re-resolves
                    self.factors[ch.table] = spmd.shard_factor(jnp.concatenate([
                        cur,
                        jnp.zeros((cap - cur.shape[0], cur.shape[1]), cur.dtype),
                    ]), self.mesh)
                # zero deleted slots BEFORE scattering fresh rows: an insert in
                # this same delta may have reused a just-deleted slot
                if len(ch.deleted):
                    gone = jnp.asarray(ch.deleted, jnp.int32)
                    self.factors[ch.table] = self.factors[ch.table].at[gone].set(0)
                if len(ch.changed):
                    self._refresh_factor_rows(ch.table, ch.changed)
                if len(ch.changed) or len(ch.deleted):
                    ti = self.schema.index[ch.table]
                    for root in self._msgs:
                        self._dirty.setdefault(root, set()).add(ti)
        self._grouped.clear()
        self.data_version += 1
        if self._stale_since is None:
            self._stale_since = time.perf_counter()
        reg = get_registry()
        reg.counter("ivm.deltas").inc(len(deltas))
        reg.histogram("ivm.apply_ms").observe((time.perf_counter() - t0) * 1e3)
        return self.data_version

    def staleness_s(self) -> float:
        """Wall-clock lag of the served view behind applied deltas: 0.0
        when every cached message/grouped score reflects the current
        ``data_version``, else seconds since the oldest unrefreshed
        delta landed.  The serving batcher mirrors this into its
        ``service.staleness_s`` gauge and the SLO staleness objective."""
        if self._stale_since is None:
            return 0.0
        return max(0.0, time.perf_counter() - self._stale_since)

    def _refresh_factor_rows(self, table: str, slots: np.ndarray):
        """Re-evaluate the stacked leaf masks for ``slots`` and scatter
        them into the live factor (elementwise per-row ops — identical
        bits to a full-table recompute of the same rows)."""
        dt = self.tables[table]
        cols = self.schema.feat_cols[table]
        k = len(slots)
        if cols:
            rows = np.stack(
                [dt.columns[c][slots].astype(np.float32) for c in cols], axis=1
            )
        else:
            rows = np.zeros((k, 0), np.float32)
        sl = jnp.asarray(slots, jnp.int32)
        if table not in self._mask_fns:
            sch, trees, dt_ = self.schema, self.trees, self.factor_dtype

            def masks(featmat, table=table):
                return stack_table_factor(sch, trees, table,
                                          featmat=featmat, dtype=dt_)

            self._mask_fns[table] = jax.jit(masks)
        # bucket the delta size to the next power of two so arbitrary
        # stream shapes hit at most log(k) jit compilations per table
        k_pad = 1 << (max(k, 1) - 1).bit_length()
        if k_pad > k:
            rows = np.concatenate(
                [rows, np.zeros((k_pad - k, rows.shape[1]), np.float32)]
            )
        frows = self._mask_fns[table](jnp.asarray(rows))
        self.factors[table] = self.factors[table].at[sl].set(frows[:k])

    # ------------------------------------------------------------- scoring --
    def _refresh_fn(self, root: str, dirty: frozenset, jt):
        """Compiled path-restricted refresh for one (root, dirty-set,
        shape fingerprint); returns (jitted fn, #edges it re-emits).
        The plan is computed ONCE from :func:`refresh_plan` — the same
        source of truth the eager route uses — so the cached program
        re-emits exactly the edges the eager route would, and the edge
        accounting (bumped eagerly by the caller) cannot drift."""
        msgs = self._msgs[root]
        fingerprint = (
            root, dirty, self.state.jt_version,
            tuple(m.shape for m in msgs),
            tuple((tn, self.factors[tn].shape) for tn in sorted(self.factors)),
        )
        hit = self._refresh_fns.get(fingerprint)
        if hit is not None:
            return hit
        sem, sp = self._sem, self._sp                # node_factor never bumps
        mesh = self.mesh
        plan = refresh_plan(jt, dirty)
        pads = [max(0, e.n_keys - msgs[i].shape[0])
                for i, e in enumerate(jt.edges)]

        def run(factors, msgs):
            new = list(msgs)
            for i, e in enumerate(jt.edges):
                if pads[i]:                          # key domain grew: ⊕-pad
                    new[i] = jnp.concatenate(
                        [new[i], sem.zeros((pads[i],))], axis=0
                    )
                if plan[i]:
                    cf = sp.node_factor(sem, factors, jt, e.child, new)
                    new[i] = spmd.psum_message(
                        sem.segment_add(cf, e.child_ids, e.n_keys), mesh)
            return new

        out = (jax.jit(run), sum(plan))
        if len(self._refresh_fns) > 128:             # bound compile cache
            self._refresh_fns.clear()
        self._refresh_fns[fingerprint] = out
        return out

    def _counts(self, group_by: str) -> jnp.ndarray:
        """Grouped leaf counts via cached messages + jitted path refresh."""
        jt = self.state.jt(group_by)
        sem, sp = self._sem, self._sp
        dirty = self._dirty.get(group_by)
        if group_by not in self._msgs:
            with spmd.use_data_mesh(self.mesh):
                self._msgs[group_by] = sp.messages(sem, self.factors, jt=jt)
        elif dirty:
            t0 = time.perf_counter()
            with span("ivm.refresh", root=group_by, dirty=len(dirty)):
                run, n_emit = self._refresh_fn(group_by, frozenset(dirty), jt)
                self._msgs[group_by] = run(self.factors, self._msgs[group_by])
            if self.counter is not None:
                self.counter.bump_edges(n_emit)
            get_registry().histogram("ivm.refresh_ms").observe(
                (time.perf_counter() - t0) * 1e3)
        self._dirty[group_by] = set()
        # all roots caught up → the served view is fresh again; record
        # how long the resolved deltas sat unserved (the delta lag)
        if self._stale_since is not None and not any(self._dirty.values()):
            reg = get_registry()
            reg.histogram("ivm.refresh_lag_s").observe(
                time.perf_counter() - self._stale_since)
            reg.gauge("ivm.staleness_s").set(0.0)
            self._stale_since = None
        # replicate before the serving contraction (see score_grouped)
        return spmd.replicate(
            sp.node_factor(sem, self.factors, jt, jt.root, self._msgs[group_by]),
            self.mesh)

    def score_grouped(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(Σŷ, |ρ⋈J|) per slot of ``group_by`` — maintained counts, same
        contraction as the compiled scorer.  Dead slots read (0, 0)."""
        if self.counter is not None:
            self.counter.bump(1)
        counts = self._counts(group_by)
        tot = (counts @ self.leaf_values).astype(jnp.float32)
        cnt = jnp.sum(counts[:, :self.tree0_leaves], axis=1).astype(jnp.float32)
        return tot, cnt

    def grouped_cached(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if group_by not in self._grouped:
            self._grouped[group_by] = self.score_grouped(group_by)
        return self._grouped[group_by]

    def recompute_oracle(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Ground-truth full recompute: a fresh static compile over the
        effective live tables (new key dictionaries, no cached state),
        evaluated through an eager message pass.  Returned arrays are
        capacity-shaped (live slots filled, dead slots 0) so they compare
        bit-for-bit against the maintained grouped output: the leaf
        counts are integer-exact either way, and routing the final
        contraction through the same-shape matvec removes the one
        remaining float-reassociation freedom (XLA's gemv blocks rows
        differently for different n, which would otherwise perturb a few
        ulps).  A jitted ``compile_ensemble(...).score_grouped`` agrees
        to allclose, not bitwise — its fused matvec reassociates."""
        eff = self.effective_schema()
        # the oracle is pinned single-device (use_data_mesh(None) clears
        # any ambient mesh): ground truth must not depend on sharding
        with spmd.use_data_mesh(None):
            fresh = compile_ensemble(eff, self.trees,
                                     factor_dtype=self.factor_dtype)
            sp = SumProd(eff)
            jt = eff.join_tree(group_by)
            msgs = sp.messages(fresh._sem, fresh.factors, jt=jt)
            counts = sp.node_factor(fresh._sem, fresh.factors, jt, jt.root, msgs)
        full = jnp.zeros(
            (self.tables[group_by].capacity, counts.shape[1]), counts.dtype
        ).at[jnp.asarray(self.live_rows(group_by), jnp.int32)].set(counts)
        tot = (full @ fresh.leaf_values).astype(jnp.float32)
        cnt = jnp.sum(full[:, :fresh.tree0_leaves], axis=1).astype(jnp.float32)
        return tot, cnt

    def score_full(self, group_by: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full-recompute reference over the SAME maintained state (every
        edge re-emitted) — the benchmark baseline for the edge-count and
        latency ratios.  Does not touch the cached messages."""
        jt = self.state.jt(group_by)
        with spmd.use_data_mesh(self.mesh):
            msgs = self._sp.messages(self._sem, self.factors, jt=jt)
        counts = spmd.replicate(
            self._sp.node_factor(self._sem, self.factors, jt, jt.root, msgs),
            self.mesh)
        tot = (counts @ self.leaf_values).astype(jnp.float32)
        cnt = jnp.sum(counts[:, :self.tree0_leaves], axis=1).astype(jnp.float32)
        return tot, cnt
