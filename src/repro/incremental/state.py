"""Shared dynamic relational state for maintained views AND retraining.

:class:`DynamicState` owns everything that makes a schema *mutable
in place with stable identities*: the capacity-padded
:class:`DynamicTable` stores, the append-only :class:`DynamicEdge` join
key dictionaries, and per-root join trees with the maintained key-id
arrays spliced into the schema's static edge order.  It applies
:class:`TableDelta` batches and reports typed :class:`TableChange`
records; what to DO about a change is the consumer's business:

- :class:`~repro.incremental.maintain.MaintainedScorer` owns its state
  and drives it through its own ``apply`` (which also re-evaluates
  stacked leaf-mask factor rows and refreshes memoized scores).
- ``MaintainedEngine`` (retrain.py) *subscribes* to its state
  (:meth:`DynamicState.subscribe`): every ``apply`` — whoever issues
  it — pushes the change records through the engine's invalidation
  hook, re-building per-table query bases and bumping content versions
  so cached boosting messages retire exactly where data changed.
  Consumers that cache derived artifacts MUST subscribe rather than
  poll; a direct ``state.apply`` then cannot leave them stale.

Concurrency: the state owns a reentrant ``lock`` serializing mutation
against snapshot capture.  :meth:`apply` holds it for the whole batch
(listeners included), so a :class:`StateView` taken under the same lock
can never observe a half-applied delta — the consistency point MVCC
snapshots (incremental/maintain.py) build on.  Reads of pinned views
then run lock-free: everything a view holds is immutable (jnp arrays,
frozen join trees, copied numpy).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np
import jax.numpy as jnp

from ..core.schema import JoinTree, Schema, Table, TreeEdge
from .deltas import DynamicEdge, DynamicTable, TableDelta


@dataclasses.dataclass(frozen=True)
class TableChange:
    """What one applied :class:`TableDelta` did to one table."""

    table: str
    changed: np.ndarray      # slots whose values changed (updates, then inserts)
    deleted: np.ndarray      # slots whose live bit was cleared
    n_inserted: int          # count of trailing insert slots in ``changed``
    grew: bool               # capacity grew (factor arrays need padding)


@dataclasses.dataclass(frozen=True)
class StateView:
    """An immutable pin of one :class:`DynamicState` version.

    Captured atomically under ``state.lock``: the version pair, the
    per-root join trees materialized at capture time (materialization
    matters — ``DynamicEdge.ids`` are numpy arrays mutated in place by
    later ``apply`` calls, but :meth:`DynamicState.jt` converts them to
    immutable jnp arrays), per-table capacities, and — when pinned for
    oracle use — a frozen effective schema plus live-slot arrays so a
    full recompute at exactly this version stays possible after the
    live state has moved on.
    """

    data_version: int
    jt_version: int
    jts: Dict[str, JoinTree]
    capacities: Dict[str, int]
    schema: Optional[Schema] = None          # effective schema (oracle pin)
    live: Optional[Dict[str, np.ndarray]] = None  # live slots per table

    def jt(self, root: str) -> JoinTree:
        if root not in self.jts:
            raise KeyError(
                f"root {root!r} not pinned in this view "
                f"(pinned: {sorted(self.jts)})"
            )
        return self.jts[root]


class DynamicState:
    """Mutable mirror of a :class:`Schema` with stable row/key identities."""

    def __init__(self, schema: Schema, slack: float = 0.25):
        self.schema = schema
        self.tables: Dict[str, DynamicTable] = {
            t.name: DynamicTable(t, slack=slack) for t in schema.tables
        }
        # one maintained key dictionary per undirected join edge
        self.edges: Dict[frozenset, DynamicEdge] = {}
        for a, b, key in schema._undirected_edges:
            self.edges[frozenset((a, b))] = DynamicEdge(
                self.tables[a], self.tables[b], key
            )
        self.data_version = 0
        self.jt_version = 0                      # bumps on any id/key change
        self._jts: Dict[str, JoinTree] = {}
        self._jt_built_at: Dict[str, int] = {}
        self._listeners: List = []
        # durable delta log (incremental/wal.py), attached via
        # ``WalWriter.attach(state)``: every applied batch is appended
        # under this lock with lsn == the data_version it produces
        self.wal = None
        # Reentrant: apply() holds it across listener callbacks, and a
        # listener may legitimately take a snapshot of the state it is
        # being notified about.
        self.lock = threading.RLock()

    def subscribe(self, fn) -> None:
        """Register a change listener: ``fn(changes)`` is called after
        every :meth:`apply` with the batch's :class:`TableChange`
        records (cache owners invalidate here, not by polling)."""
        self._listeners.append(fn)

    # ------------------------------------------------------------- queries --
    def capacity(self, table: str) -> int:
        return self.tables[table].capacity

    def live_rows(self, table: str) -> np.ndarray:
        return self.tables[table].live_slots()

    def feature_rows(self, table: str, slots: np.ndarray) -> np.ndarray:
        """(len(slots), d_t) float32 feature values at ``slots``, dead
        slots pushed to +inf — the payload incremental split-plan
        maintenance re-bins (see ``core.hist.rebin_rows``): a dead
        slot's stale column values must neither bin validly nor ever
        become a threshold."""
        dt = self.tables[table]
        cols = self.schema.feat_cols[table]
        slots = np.asarray(slots, np.int64)
        if not cols:
            return np.zeros((len(slots), 0), np.float32)
        vals = np.stack(
            [dt.columns[c][slots].astype(np.float32) for c in cols], axis=1
        )
        vals[~dt.live[slots]] = np.inf
        return vals

    def effective_schema(self) -> Schema:
        """A fresh static Schema over the live rows (slot order) — the
        full-recompute oracle maintained results must match."""
        return Schema(
            [self.tables[t.name].effective() for t in self.schema.tables],
            label=(self.schema.label_table, self.schema.label_column),
        )

    def jt(self, root: str) -> JoinTree:
        """Join tree for ``root`` with the MAINTAINED key-id arrays spliced
        into the schema's static edge order."""
        if self._jt_built_at.get(root) == self.jt_version and root in self._jts:
            return self._jts[root]
        base = self.schema.join_tree(root)
        names = self.schema.names
        edges = []
        for e in base.edges:
            de = self.edges[frozenset((names[e.child], names[e.parent]))]
            # .copy() is load-bearing: jnp.asarray of a same-dtype numpy
            # array is ZERO-COPY on CPU, and DynamicEdge.assign mutates
            # `ids` in place — without the copy a pinned join tree's id
            # arrays change under a concurrent reader (a reused slot's
            # contribution migrates to the wrong segment: a torn read)
            edges.append(TreeEdge(
                child=e.child, parent=e.parent, key_cols=e.key_cols,
                child_ids=jnp.asarray(de.ids[names[e.child]].copy(), jnp.int32),
                parent_ids=jnp.asarray(de.ids[names[e.parent]].copy(), jnp.int32),
                n_keys=de.n_keys,
            ))
        jt = JoinTree(root=base.root, edges=tuple(edges))
        self._jts[root] = jt
        self._jt_built_at[root] = self.jt_version
        return jt

    def snapshot(self, roots: Sequence[str], pin_oracle: bool = False) -> StateView:
        """Pin an immutable :class:`StateView` at the current version.

        ``roots`` selects which join trees to materialize; with
        ``pin_oracle=True`` the effective schema and live-slot arrays
        are frozen too (copied — ``DynamicTable.live`` mutates in
        place), enabling bit-exact full recompute at this version
        arbitrarily far in the future.
        """
        with self.lock:
            jts = {r: self.jt(r) for r in roots}
            caps = {t: dt.capacity for t, dt in self.tables.items()}
            sch = live = None
            if pin_oracle:
                sch = self.effective_schema()
                live = {t: dt.live_slots().copy() for t, dt in self.tables.items()}
            return StateView(
                data_version=self.data_version, jt_version=self.jt_version,
                jts=jts, capacities=caps, schema=sch, live=live,
            )

    # -------------------------------------------------------------- deltas --
    def apply(self, deltas: Sequence[TableDelta]) -> List[TableChange]:
        """Apply a delta batch to the stores and key dictionaries;
        returns per-delta change records in application order.  Bumps
        ``jt_version`` on structural change (inserts / capacity growth)
        and ``data_version`` once per batch."""
        if isinstance(deltas, TableDelta):
            deltas = [deltas]
        with self.lock:
            return self._apply_locked(deltas)

    def _apply_locked(self, deltas: Sequence[TableDelta]) -> List[TableChange]:
        changes: List[TableChange] = []
        structural = False
        for d in deltas:
            if d.table not in self.tables:
                raise KeyError(f"unknown table {d.table!r}")
            dt = self.tables[d.table]
            if d.updates is not None:
                key_cols = {c for e in self.edges.values()
                            if d.table in e.tables for c in e.key_cols}
                bad = key_cols & set(d.updates[1])
                if bad:
                    raise ValueError(
                        f"update of join-key columns {sorted(bad)} on "
                        f"{d.table!r}: issue delete + insert instead"
                    )
            deleted = (np.unique(np.asarray(d.deletes, np.int64))
                       if d.deletes is not None and len(d.deletes)
                       else np.zeros((0,), np.int64))
            n_ins = (len(next(iter(d.inserts.values()))) if d.inserts else 0)
            changed, grew = dt.apply(d)
            if grew:
                structural = True
            # inserts (tail of `changed`) need key ids on incident edges;
            # key-domain growth is absorbed by ⊕-identity padding of any
            # cached messages, so only the id arrays (→ join trees) go
            # stale here
            if n_ins:
                structural = True
                ins_slots = changed[-n_ins:]
                for e in self.edges.values():
                    if d.table in e.tables:
                        e.assign(dt, ins_slots)
            changes.append(TableChange(
                table=d.table, changed=changed, deleted=deleted,
                n_inserted=n_ins, grew=grew,
            ))
        if structural:
            self.jt_version += 1
        # WAL append sits AFTER the mutations (which can only raise
        # before touching anything durable) and BEFORE the version bump:
        # the log carries exactly the committed versions in order, and a
        # crash in the append window loses only in-memory state — which
        # the crash loses anyway — never a logged-but-unapplied version
        if self.wal is not None:
            self.wal.append(self.data_version + 1, deltas)
        self.data_version += 1
        for fn in self._listeners:
            fn(changes)
        return changes
