"""Fault tolerance: step watchdog, straggler detection, retry-with-restore.

At thousand-node scale the failure model is (a) hard device loss →
restart from checkpoint on a rebuilt mesh (runtime/elastic.py), (b) soft
stragglers (one host 2-10× slow) → detect via step-time outliers and
reassign its input shard (data pipeline) while the SPMD program keeps
running, (c) transient step failure (preemption, IO) → retry, then
restore-and-continue.  All hooks are exercised by tests with simulated
failures.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StepWatchdog:
    """EMA step-timer; flags steps slower than `threshold` × EMA."""

    threshold: float = 3.0
    decay: float = 0.9
    warmup: int = 3
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    _ema: float = 0.0
    _n: int = 0
    straggler_steps: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is flagged."""
        self._n += 1
        if self._n <= self.warmup:
            self._ema = dt if self._ema == 0 else (
                self.decay * self._ema + (1 - self.decay) * dt
            )
            return False
        flagged = dt > self.threshold * self._ema
        if flagged:
            self.straggler_steps.append(step)
            if self.on_straggler:
                self.on_straggler(step, dt, self._ema)
        else:  # don't poison the EMA with outliers
            self._ema = self.decay * self._ema + (1 - self.decay) * dt
        return flagged

    def time_step(self, step: int):
        return _Timer(self, step)


class _Timer:
    def __init__(self, wd: StepWatchdog, step: int):
        self.wd, self.step = wd, step

    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        if exc[0] is None:
            self.wd.observe(self.step, time.monotonic() - self.t0)
        return False


class Backoff:
    """Jittered exponential backoff with a hard retry-time budget.

    ``next_delay()`` returns the next sleep (seconds): exponential from
    ``base_s`` up to ``cap_s``, multiplied by a uniform jitter in
    ``[1 - jitter, 1]`` so synchronized retriers (e.g. several WAL
    followers tailing one log) de-correlate.  Once the cumulative delay
    would exceed ``budget_s`` it raises ``RuntimeError`` — a retry loop
    with a budget can stall, never hang.  ``reset()`` after a success;
    ``clone()`` gives an independent instance with the same policy
    (per-thread state, shared configuration).
    """

    def __init__(self, base_s: float = 0.01, cap_s: float = 1.0,
                 budget_s: float = 30.0, jitter: float = 0.5,
                 seed: Optional[int] = None):
        self.base_s = base_s
        self.cap_s = cap_s
        self.budget_s = budget_s
        self.jitter = jitter
        self._seed = seed
        self._rng = random.Random(seed)
        self._attempt = 0
        self._spent = 0.0

    def next_delay(self) -> float:
        raw = min(self.cap_s, self.base_s * (2.0 ** self._attempt))
        delay = raw * (1.0 - self.jitter * self._rng.random())
        if self._spent + delay > self.budget_s:
            raise RuntimeError(
                f"retry budget exhausted after {self._attempt} attempts "
                f"({self._spent:.2f}s of {self.budget_s:.2f}s)")
        self._attempt += 1
        self._spent += delay
        return delay

    def reset(self) -> None:
        self._attempt = 0
        self._spent = 0.0

    def clone(self) -> "Backoff":
        return Backoff(self.base_s, self.cap_s, self.budget_s,
                       self.jitter, self._seed)


def run_with_retries(step_fn, state, batch, *, retries: int = 2,
                     on_failure: Optional[Callable[[int, Exception], None]] = None):
    """Execute one training step with bounded retries.  The caller's
    state is pure (JAX), so a retry is safe; repeated failure escalates
    to the restore path (train.py catches and restores the last
    checkpoint on a rebuilt mesh)."""
    last = None
    for attempt in range(retries + 1):
        try:
            return step_fn(state, batch)
        except Exception as e:  # noqa: BLE001 — deliberate boundary
            last = e
            if on_failure:
                on_failure(attempt, e)
    raise last


class FaultInjector:
    """Test utility: raises on selected steps (once each)."""

    def __init__(self, fail_steps):
        self.fail_steps = set(fail_steps)
        self.failed = set()

    def maybe_fail(self, step: int):
        if step in self.fail_steps and step not in self.failed:
            self.failed.add(step)
            raise RuntimeError(f"injected fault at step {step}")
