"""Fault tolerance: step watchdog, straggler detection, retry-with-restore.

At thousand-node scale the failure model is (a) hard device loss →
restart from checkpoint on a rebuilt mesh (runtime/elastic.py), (b) soft
stragglers (one host 2-10× slow) → detect via step-time outliers and
reassign its input shard (data pipeline) while the SPMD program keeps
running, (c) transient step failure (preemption, IO) → retry, then
restore-and-continue.  All hooks are exercised by tests with simulated
failures.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StepWatchdog:
    """EMA step-timer; flags steps slower than `threshold` × EMA."""

    threshold: float = 3.0
    decay: float = 0.9
    warmup: int = 3
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    _ema: float = 0.0
    _n: int = 0
    straggler_steps: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is flagged."""
        self._n += 1
        if self._n <= self.warmup:
            self._ema = dt if self._ema == 0 else (
                self.decay * self._ema + (1 - self.decay) * dt
            )
            return False
        flagged = dt > self.threshold * self._ema
        if flagged:
            self.straggler_steps.append(step)
            if self.on_straggler:
                self.on_straggler(step, dt, self._ema)
        else:  # don't poison the EMA with outliers
            self._ema = self.decay * self._ema + (1 - self.decay) * dt
        return flagged

    def time_step(self, step: int):
        return _Timer(self, step)


class _Timer:
    def __init__(self, wd: StepWatchdog, step: int):
        self.wd, self.step = wd, step

    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        if exc[0] is None:
            self.wd.observe(self.step, time.monotonic() - self.t0)
        return False


def run_with_retries(step_fn, state, batch, *, retries: int = 2,
                     on_failure: Optional[Callable[[int, Exception], None]] = None):
    """Execute one training step with bounded retries.  The caller's
    state is pure (JAX), so a retry is safe; repeated failure escalates
    to the restore path (train.py catches and restores the last
    checkpoint on a rebuilt mesh)."""
    last = None
    for attempt in range(retries + 1):
        try:
            return step_fn(state, batch)
        except Exception as e:  # noqa: BLE001 — deliberate boundary
            last = e
            if on_failure:
                on_failure(attempt, e)
    raise last


class FaultInjector:
    """Test utility: raises on selected steps (once each)."""

    def __init__(self, fail_steps):
        self.fail_steps = set(fail_steps)
        self.failed = set()

    def maybe_fail(self, step: int):
        if step in self.fail_steps and step not in self.failed:
            self.failed.add(step)
            raise RuntimeError(f"injected fault at step {step}")
