"""Elastic scaling: rebuild the mesh after device-set changes and
reshard state from the last checkpoint.

The checkpoint format is mesh-agnostic (global arrays restored through
jax.make_array_from_callback against the *target* sharding), so
downscaling 512→256 or reshaping (data, model) is a restore, not a
conversion.  tests/test_checkpoint.py exercises a cross-device-count
restore in a subprocess.
"""
from __future__ import annotations

from typing import Tuple

import jax


def plan_mesh(n_devices: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid for the surviving device set.  Model
    parallelism is fixed by the checkpointed layout preference; data
    parallelism absorbs the loss."""
    model = model_parallel
    while model > 1 and n_devices % model:
        model //= 2
    return n_devices // model, model


def rebuild_mesh(model_parallel: int):
    n = len(jax.devices())
    data, model = plan_mesh(n, model_parallel)
    return jax.make_mesh((data, model), ("data", "model"))


def restore_elastic(ckpt, step, like, mesh, sharding_fn):
    """Restore `like`-shaped state onto `mesh` (any size).

    sharding_fn(mesh, like) → shardings pytree (e.g. param_shardings)."""
    return ckpt.restore(step, like, sharding_fn(mesh, like))
