"""Quickstart: train boosted regression trees DIRECTLY on a relational
database — no design-matrix materialization — exactly the paper's
setting, then verify against the materialized-join baseline.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import (
    BoostConfig, Booster, MaterializedBooster, materialize_join, predict_rows,
)
from repro.relational.generators import star_schema


def main():
    # A star schema: fact table (events) joined to two dimension tables.
    # J = fact ⋈ dim0 ⋈ dim1 is never built by the relational algorithms.
    schema = star_schema(seed=0, n_fact=2000, n_dim=64, n_dim_tables=2,
                         feats_per_dim=2, fact_feats=2)
    print("tables:", {t.name: t.n_rows for t in schema.tables})

    # --- Algorithm 3: sketched relational boosting (the paper's headline)
    cfg = BoostConfig(n_trees=5, depth=3, mode="sketch", sketch_k=256)
    t0 = time.time()
    booster = Booster(schema, cfg)
    trees, trace = booster.fit()
    print(f"sketched relational fit: {time.time()-t0:.1f}s, "
          f"{trace.queries} SumProd queries")

    # --- sanity: the materialized-join baseline learns the same model
    J = materialize_join(schema)
    X = jnp.stack([J[c] for (_, c) in schema.features], axis=1)
    y = J[schema.label_column]
    trees_mat = MaterializedBooster(X, y, cfg).fit()
    p_rel = predict_rows(trees, X)
    p_mat = predict_rows(trees_mat, X)
    print(f"|J| = {X.shape[0]} rows (materialized only for this check)")
    print(f"relational MSE  = {float(jnp.mean((y - p_rel) ** 2)):.4f}")
    print(f"materialized MSE= {float(jnp.mean((y - p_mat) ** 2)):.4f}")
    print(f"var(y)          = {float(jnp.var(y)):.4f}")
    print(f"max |pred diff| = {float(jnp.abs(p_rel - p_mat).max()):.2e}")

    # --- relational scoring: per-fact-row predictions without the join
    tot, cnt = booster.predict_grouped(trees, "fact")
    print("per-fact-row scores (first 5):",
          np.round(np.asarray(tot[:5] / jnp.maximum(cnt[:5], 1)), 3))


if __name__ == "__main__":
    main()
