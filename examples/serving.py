"""Serving examples — relational scorer by default, LM stack via --lm.

Relational path (the paper's workload): train boosted trees in-database,
compile the ensemble into the one-pass scorer, and serve interactive
row-score traffic through the micro-batching service.  Exits with a
one-screen metrics summary table (latency quantiles, batch sizes, cache
hit rates — see src/repro/obs/); pass ``--trace out.json`` to also
record a Chrome trace of the run, loadable in Perfetto:

    PYTHONPATH=src python examples/serving.py

LM path (prefill + greedy decode on the reduced MoE config):

    PYTHONPATH=src python examples/serving.py --lm
"""
import sys

from repro.launch import serve, serve_relational


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--lm" in argv:
        argv.remove("--lm")
        return serve.main(argv or [
            "--arch", "dbrx_132b", "--batch", "4",
            "--prompt-len", "64", "--decode-tokens", "32",
        ])
    return serve_relational.main(argv or [
        "--n-fact", "1000", "--trees", "4", "--requests", "1000",
    ])


if __name__ == "__main__":
    main()
