"""Batched serving example: prefill + greedy decode on the reduced MoE
config (dbrx family) — exercises the KV cache, MoE near-dropless
inference dispatch, and the decode step the dry-run lowers at 32k/500k.

    PYTHONPATH=src python examples/serving.py
"""
from repro.launch import serve


def main():
    serve.main([
        "--arch", "dbrx_132b", "--batch", "4",
        "--prompt-len", "64", "--decode-tokens", "32",
    ])


if __name__ == "__main__":
    main()
