"""End-to-end LM training driver on CPU: a reduced tinyllama-family model
(~10M params) for a few hundred steps through the FULL production stack —
pipeline → microbatched train step → watchdog → async checkpoints →
resume.  Loss should fall well below the unigram floor.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import sys

from repro.launch import train


def main():
    args = [
        "--arch", "tinyllama_1_1b", "--steps", "300", "--batch", "8",
        "--seq", "128", "--n-micro", "2", "--lr", "3e-3",
        "--ckpt-dir", "/tmp/repro_lm_ckpt", "--ckpt-every", "100",
    ]
    # pass-through overrides (e.g. --steps 50)
    extra = sys.argv[1:]
    for i in range(0, len(extra) - 1, 2):
        if extra[i] in args:
            args[args.index(extra[i]) + 1] = extra[i + 1]
    train.main(args)


if __name__ == "__main__":
    main()
