"""The paper's technique as a production data-pipeline stage: train a
boosted regressor relationally over document *metadata tables* (never
joining them), score every document, and use the scores as sampling
weights for LM pretraining data mixing.

    PYTHONPATH=src python examples/relational_data_pipeline.py
"""
import numpy as np

from repro.core import BoostConfig, Booster
from repro.data.pipeline import TokenPipeline, relational_example_weights
from repro.relational.generators import star_schema


def main():
    # fact = documents; dims = source/domain metadata.  The label column
    # is a quality rating available for a subset pipeline-side.
    schema = star_schema(seed=4, n_fact=1000, n_dim=32)
    cfg = BoostConfig(n_trees=4, depth=3, mode="sketch", sketch_k=256,
                      ssr_mode="off")   # production fast path
    booster = Booster(schema, cfg)
    trees, trace = booster.fit()
    print(f"quality model fit relationally: {trace.queries} SumProd queries")

    weights = relational_example_weights(booster, trees, "fact")
    print("weight stats: min %.2e  max %.2e  (effective sample size %.0f/%d)" % (
        weights.min(), weights.max(), 1.0 / np.square(weights).sum(), len(weights)))

    pipe = TokenPipeline(vocab=512, global_batch=8, seq_len=64, seed=0,
                         example_weights=weights)
    batch = next(pipe)
    pipe.stop()
    print("first weighted batch:", batch["tokens"].shape, batch["tokens"].dtype)


if __name__ == "__main__":
    main()
