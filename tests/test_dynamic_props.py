"""Property suite: DynamicTable / DynamicEdge / DynamicState invariants
under random insert/delete/update streams — stable slot ids, append-only
key dictionaries, capacity growth, live-row masks.

Hypothesis-driven when available (requirements-dev.txt); the seeded
deterministic sweeps below exercise the same model-based checker so
tier-1 keeps real coverage when hypothesis is absent
(tests/_hypothesis_compat.py makes the @given tests skip cleanly)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # pragma: no cover
    from _hypothesis_compat import given, settings, st

from repro.core import Table
from repro.incremental import (
    DynamicEdge, DynamicState, DynamicTable, TableDelta,
)
from repro.relational.generators import delta_stream, star_schema

N_KEYS = 5          # base join-key domain; inserts may mint keys beyond it


def _base_table(n=6):
    return Table(
        name="t",
        columns={
            "k": (np.arange(n) % N_KEYS).astype(np.int64),
            "x": np.arange(n, dtype=np.float32),
        },
        feature_columns=("x",),
    )


def _check_invariants(dt, edge, shadow, prev_keys):
    """One full audit of the dynamic pair against the shadow model
    (slot → expected row values for every live row)."""
    # live-row mask ≡ shadow domain; capacity only ever covers it
    assert dt.n_live == len(shadow)
    np.testing.assert_array_equal(
        dt.live_slots(), np.asarray(sorted(shadow), np.int64)
    )
    assert dt.capacity >= dt.n_live
    assert len(dt.live) == dt.capacity
    for c in dt.columns:
        assert len(dt.columns[c]) == dt.capacity
    # stable slot ids: every surviving row reads back its exact values
    for s, row in shadow.items():
        for c, v in row.items():
            assert dt.columns[c][s] == v, (s, c)
    # append-only key dictionary: the previous mapping survives verbatim
    for key, kid in prev_keys.items():
        assert edge.key_to_id[key] == kid
    ids = edge.ids["t"]
    assert len(ids) == dt.capacity
    assert edge.n_keys == max(len(edge.key_to_id), 1)
    # every live slot carries the id of its key tuple
    for s, row in shadow.items():
        assert ids[s] == edge.key_to_id[(row["k"],)]
    # effective(): live rows in slot order, values verbatim
    eff = dt.effective()
    slots = sorted(shadow)
    assert eff.n_rows == len(slots)
    for c in eff.columns:
        np.testing.assert_array_equal(
            eff.columns[c],
            np.asarray([shadow[s][c] for s in slots], eff.columns[c].dtype),
        )


def _apply_ops(ops):
    """Drive a DynamicTable + incident DynamicEdge through an op stream,
    auditing the invariants after every delta."""
    t = _base_table()
    other = Table(name="o", columns={"k": np.arange(N_KEYS, dtype=np.int64)})
    dt = DynamicTable(t, slack=0.34)
    do = DynamicTable(other, slack=0.34)
    edge = DynamicEdge(dt, do, ("k",))
    shadow = {
        s: {c: dt.columns[c][s] for c in dt.columns} for s in range(t.n_rows)
    }
    next_x = float(t.n_rows)
    prev_keys = dict(edge.key_to_id)
    for kind, arg in ops:
        live = sorted(shadow)
        if kind == "insert":
            k = 1 + arg % 3
            keys = np.asarray(
                [(arg + i) % (N_KEYS + 2) for i in range(k)], np.int64
            )
            xs = np.asarray([next_x + i for i in range(k)], np.float32)
            next_x += k
            changed, _grew = dt.apply(
                TableDelta("t", inserts={"k": keys, "x": xs})
            )
            ins = changed[-k:]
            edge.assign(dt, ins)
            for s, kk, xx in zip(ins, keys, xs):
                assert int(s) not in shadow      # inserts fill dead slots only
                shadow[int(s)] = {"k": kk, "x": xx}
        elif kind == "delete":
            if len(live) <= 1:
                continue
            s = live[arg % len(live)]
            dt.apply(TableDelta("t", deletes=np.asarray([s])))
            del shadow[s]
        else:                                    # update of a non-key column
            s = live[arg % len(live)]
            xs = np.asarray([next_x], np.float32)
            next_x += 1
            dt.apply(TableDelta("t", updates=(np.asarray([s]), {"x": xs})))
            shadow[s]["x"] = xs[0]
        _check_invariants(dt, edge, shadow, prev_keys)
        prev_keys = dict(edge.key_to_id)


@settings(max_examples=50, deadline=None)
@given(st.lists(
    st.tuples(st.sampled_from(["insert", "delete", "update"]),
              st.integers(0, 10 ** 6)),
    max_size=40,
))
def test_dynamic_store_invariants_hypothesis(ops):
    _apply_ops(ops)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_dynamic_store_invariants_seeded(seed):
    """Deterministic sweep over the same checker (runs without
    hypothesis; biased toward inserts so capacity growth triggers)."""
    rng = np.random.default_rng(seed)
    kinds = ["insert", "insert", "delete", "update"]
    ops = [(kinds[int(rng.integers(len(kinds)))], int(rng.integers(10 ** 6)))
           for _ in range(60)]
    _apply_ops(ops)


def test_insert_burst_forces_capacity_growth():
    """Growth path pinned explicitly: a burst larger than the free-slot
    pool doubles capacity while every pre-existing live row keeps its
    slot, values, and key id."""
    ops = [("insert", 2)] * 12                   # 3 rows per op, slack 0.34
    _apply_ops(ops)


def test_dynamic_state_version_semantics():
    """DynamicState: data_version bumps per batch; jt_version bumps only
    on structural change (inserts/growth), never on value updates."""
    sch = star_schema(seed=21, n_fact=40, n_dim=6)
    state = DynamicState(sch, slack=0.25)
    jv0, dv0 = state.jt_version, state.data_version
    upd = TableDelta("dim0", updates=(
        np.asarray([0, 1]),
        {c: np.zeros(2, np.float32) for c in sch.table("dim0").feature_columns},
    ))
    state.apply([upd])
    assert state.data_version == dv0 + 1
    assert state.jt_version == jv0               # pure update: not structural
    fact = sch.table("fact")
    row = {c: np.zeros(1, np.asarray(fact.col(c)).dtype) for c in fact.columns}
    changes = state.apply([TableDelta("fact", inserts=row)])
    assert state.jt_version == jv0 + 1           # insert assigns key ids
    assert changes[0].n_inserted == 1
    # the maintained join tree reflects the new slot's key assignment
    jt = state.jt("fact")
    cap = state.capacity("fact")
    for e in jt.edges:
        ids = e.parent_ids if e.parent == jt.root else e.child_ids
        if len(ids) == cap:
            break
    else:
        pytest.fail("no maintained id array sized to the fact capacity")


def test_dynamic_state_random_stream_effective_schema_consistent():
    """Model check at the state level: after an arbitrary churn stream,
    effective_schema() row counts and live sets agree with the stores."""
    sch = star_schema(seed=22, n_fact=50, n_dim=8)
    state = DynamicState(sch, slack=0.1)
    for batch in delta_stream(sch, state.live_rows, seed=23,
                              n_batches=6, ops_per_batch=6):
        state.apply(batch)
    eff = state.effective_schema()
    for t in sch.tables:
        assert eff.table(t.name).n_rows == state.tables[t.name].n_live
