"""Fault-injection harness for the durability tests.

The WAL/checkpoint code calls an injectable ``fault(point, **ctx)`` hook
at every durability-relevant step (``append.before`` / ``append.write``
/ ``append.after`` / ``sync.before`` / ``sync.after`` /
``ckpt.before_rename`` / ``ckpt.after_rename`` / ``ckpt.after``).  A
:class:`FaultPlan` is that hook: it raises :class:`CrashPoint` at one
chosen point (optionally only on its Nth hit), or — for
``append.write`` — returns a torn byte count so the writer persists a
prefix of the record and then dies.

On top of the in-process crash points, :func:`flip_tail_bit` and
:func:`truncate_tail` damage a closed log file the way real storage
does (bit rot, lost sectors), so recovery's checksum path is exercised
against byte-level corruption, not just clean process death.
"""
import os


class CrashPoint(BaseException):
    """Simulated process death at an injected fault point.

    Derives from ``BaseException`` so production ``except Exception``
    handlers cannot accidentally absorb a simulated crash — exactly
    like a real SIGKILL, nothing downstream of the point runs.
    """


class FaultPlan:
    """Callable fault hook: die at ``crash_at`` on its ``on_hit``-th hit.

    ``tear`` (``append.write`` only) persists that many bytes of the
    record buffer before dying — a torn write.  The plan fires at most
    once; after firing it is inert, so the recovery path can reuse the
    same writer objects without re-crashing.
    """

    def __init__(self, crash_at=None, on_hit=1, tear=None):
        self.crash_at = crash_at
        self.on_hit = on_hit
        self.tear = tear
        self.hits = {}
        self.fired = False

    def __call__(self, point, **ctx):
        n = self.hits.get(point, 0) + 1
        self.hits[point] = n
        if self.fired or point != self.crash_at or n != self.on_hit:
            return None
        self.fired = True
        if point == "append.write" and self.tear is not None:
            buf = ctx.get("buf", b"")
            return max(0, min(self.tear, max(0, len(buf) - 1)))
        raise CrashPoint(f"injected crash at {point} (hit {n})")


def flip_tail_bit(path: str, back: int = 3) -> None:
    """Flip one bit ``back`` bytes from the end of ``path`` (bit rot in
    the newest record — the checksum must catch it)."""
    size = os.path.getsize(path)
    at = max(0, size - back)
    with open(path, "r+b") as f:
        f.seek(at)
        b = f.read(1)
        f.seek(at)
        f.write(bytes([b[0] ^ 0x40]))


def truncate_tail(path: str, nbytes: int) -> None:
    """Drop the last ``nbytes`` of ``path`` (a lost sector / partial
    flush at the tail)."""
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(0, size - nbytes))
