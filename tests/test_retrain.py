"""Incremental relational boosting: the maintained-message engine must
answer the Booster's node-statistics queries EXACTLY like the direct
per-query engine — identical trees on fresh fits, identical warm-start
trees after delta streams (differential vs a from-scratch Booster on the
effective live tables) — while emitting strictly fewer segment-⊕
messages; plus drift-gated refit semantics and engine-level units."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    BoostConfig, Booster, QueryCounter, materialize_join, predict_rows,
)
from repro.incremental import IncrementalBooster, TableDelta
from repro.relational.generators import (
    chain_schema, delta_stream, drift_stream, snowflake_schema, star_schema,
)

CFG = dict(n_trees=2, depth=2, mode="sketch", ssr_mode="off")


def _small(shape):
    if shape == "star":
        return star_schema(seed=31, n_fact=100, n_dim=10)
    if shape == "chain":
        return chain_schema(seed=32, n_rows=60, n_tables=3, fanout=2)
    return snowflake_schema(seed=33, n_fact=70, n_dim=8, n_sub=4)


def _assert_trees_match(a, b, atol=1e-5):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x.feat), np.asarray(y.feat))
        np.testing.assert_allclose(np.asarray(x.thr), np.asarray(y.thr),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(x.leaf), np.asarray(y.leaf),
                                   rtol=1e-4, atol=atol)


# ------------------------------------------------------------ fresh fits --

@pytest.mark.parametrize("shape", ["star", "chain", "snowflake"])
def test_fresh_fit_matches_direct_engine(shape):
    """Same queries, different evaluation route ⇒ the same model, for
    strictly fewer segment-⊕ emissions."""
    sch = _small(shape)
    cfg = BoostConfig(**CFG)
    ib = IncrementalBooster(sch, cfg)
    trees_i, _ = ib.fit()
    direct = Booster(sch, cfg)
    trees_d, _ = direct.fit()
    _assert_trees_match(trees_i, trees_d)
    assert ib.counter.count == direct.counter.count      # same logical queries
    assert ib.counter.edges < direct.counter.edges       # fewer emissions
    assert ib.engine.cache.hits > 0


def test_exact_mode_matches_direct_engine_with_ssr():
    """Exact mode exercises the leaf-pair count queries and the SSR
    trace through the maintained engine."""
    sch = _small("star")
    cfg = BoostConfig(n_trees=2, depth=2, mode="exact", ssr_mode="per_table")
    ib = IncrementalBooster(sch, cfg)
    trees_i, tr_i = ib.fit()
    direct = Booster(sch, cfg)
    trees_d, tr_d = direct.fit()
    _assert_trees_match(trees_i, trees_d)
    assert len(tr_i.node_ssr) == len(tr_d.node_ssr)
    for si, sd in zip(tr_i.node_ssr, tr_d.node_ssr):
        for tbl in sd:
            np.testing.assert_allclose(np.asarray(si[tbl]),
                                       np.asarray(sd[tbl]),
                                       rtol=1e-4, atol=1e-3)


# ------------------------------------------------- differential warm start --

@pytest.mark.parametrize("shape", ["star", "chain", "snowflake"])
def test_refit_on_delta_stream_matches_scratch_booster(shape):
    """THE tentpole differential: after an arbitrary churn stream
    (inserts with fresh join keys, deletes, updates, capacity growth),
    warm-starting through the maintained engine must produce the same
    new trees (f32 splits and leaf values) as a from-scratch direct
    Booster on the effective live tables, warm-started from the same
    frozen prefix."""
    sch = _small(shape)
    cfg = BoostConfig(**CFG)
    ib = IncrementalBooster(sch, cfg)
    ib.fit()
    frozen = list(ib.trees)
    for batch in delta_stream(sch, ib.live_rows, seed=37, n_batches=3,
                              ops_per_batch=5):
        ib.apply(batch)
    e0 = ib.counter.edges
    rep = ib.refit(n_new_trees=2, drift_threshold=-np.inf)
    assert rep.refitted and rep.n_new == 2 and len(ib.trees) == 4
    inc_edges = ib.counter.edges - e0

    eff = ib.effective_schema()
    oracle = Booster(eff, cfg)
    trees_o, _ = oracle.boost(list(frozen), 2)
    _assert_trees_match(ib.trees, trees_o)
    # frozen prefix untouched by the refit
    for a, b in zip(ib.trees[:2], frozen):
        assert a is b
    # and the maintained delta-epoch emitted fewer edges than the oracle's
    # warm start alone would (which itself is cheaper than its full fit)
    assert inc_edges < oracle.counter.edges


def test_refit_quality_parity_under_drift():
    """Concept drift: refit model's MSE on the live join matches the
    full-refit oracle within the sketching-tolerance band (gap ≤ 5% of
    label variance)."""
    sch = star_schema(seed=35, n_fact=120, n_dim=12)
    cfg = BoostConfig(**CFG)
    ib = IncrementalBooster(sch, cfg)
    ib.fit()
    for batch in drift_stream(sch, ib.live_rows, seed=36, n_batches=2,
                              rows_per_batch=4):
        rep = ib.refit(deltas=batch, n_new_trees=2, drift_threshold=0.0)
    eff = ib.effective_schema()
    full = Booster(eff, BoostConfig(n_trees=len(ib.trees), depth=2,
                                    mode="sketch", ssr_mode="off"))
    trees_f, _ = full.fit()
    J = materialize_join(eff)
    X = jnp.stack([J[c] for (_, c) in eff.features], axis=1)
    y = np.asarray(J[eff.label_column])
    mse_i = float(np.mean((y - np.asarray(predict_rows(ib.trees, X))) ** 2))
    mse_f = float(np.mean((y - np.asarray(predict_rows(trees_f, X))) ** 2))
    var = float(np.var(y))
    assert (mse_i - mse_f) / var <= 0.05, (mse_i, mse_f, var)
    assert mse_i < 0.5 * var                 # and the model is actually good


@pytest.mark.parametrize("shape", ["star", "chain", "snowflake"])
def test_hist_refit_on_delta_stream_matches_scratch_booster(shape):
    """The exact-mode differential, in histogram split mode: with
    edge_tol=0 every dirty table re-quantizes its bin edges from the
    live values, so after an arbitrary churn stream the maintained
    warm start must select the same trees as a fresh hist-mode Booster
    on the effective live tables (same frozen prefix) — binning, sweep,
    and maintained queries all agree with the from-scratch route."""
    sch = _small(shape)
    cfg = BoostConfig(**CFG, split_mode="hist", hist_bins=32,
                      hist_edge_tol=0.0)
    ib = IncrementalBooster(sch, cfg)
    ib.fit()
    frozen = list(ib.trees)
    for batch in delta_stream(sch, ib.live_rows, seed=47, n_batches=3,
                              ops_per_batch=5):
        ib.apply(batch)
    rep = ib.refit(n_new_trees=2, drift_threshold=-np.inf)
    assert rep.refitted and len(ib.trees) == 4

    eff = ib.effective_schema()
    oracle = Booster(eff, cfg)
    trees_o, _ = oracle.boost(list(frozen), 2)
    _assert_trees_match(ib.trees, trees_o)
    for a, b in zip(ib.trees[:2], frozen):
        assert a is b


# ------------------------------------------------------- refit semantics --

def test_refit_drift_gate_and_tree_budget():
    sch = star_schema(seed=41, n_fact=80, n_dim=8)
    cfg = BoostConfig(**CFG)
    ib = IncrementalBooster(sch, cfg)
    ib.fit()
    # unchanged data: drift 0 → gate holds, no trees, and the drift
    # check itself is fully served from the message cache (0 emissions)
    rep = ib.refit(n_new_trees=2, drift_threshold=0.01)
    assert not rep.refitted and rep.n_new == 0 and rep.edges == 0
    assert rep.drift == pytest.approx(0.0, abs=1e-9)

    rng = np.random.default_rng(0)
    def drift_batch():
        live = ib.live_rows("fact")[:6]
        return [TableDelta("fact", updates=(
            live, {"y": (10.0 + rng.standard_normal(len(live))).astype(np.float32)}
        ))]

    # a real label shift: gate opens
    rep = ib.refit(deltas=drift_batch(), n_new_trees=1, drift_threshold=0.01)
    assert rep.refitted and rep.drift > 0.01 and len(ib.trees) == 3
    assert rep.mse_after <= rep.mse_before + 1e-6

    # absurd threshold: gate holds even under drift
    rep = ib.refit(deltas=drift_batch(), n_new_trees=1, drift_threshold=1e9)
    assert not rep.refitted and len(ib.trees) == 3

    # tree budget: most recent trees are replaced, oldest survive
    t0 = ib.trees[0]
    rep = ib.refit(deltas=drift_batch(), n_new_trees=2,
                   drift_threshold=-np.inf, max_trees=3)
    assert rep.refitted and len(ib.trees) == 3
    assert ib.trees[0] is t0


# --------------------------------------------------------- engine units --

def test_engine_grouped_c3_matches_direct_and_memoizes():
    """Unit check of the memoized message pass: capacity-shaped grouped
    stats equal the direct engine's on the live slots (dead slots 0),
    for non-uniform node masks; repeating the family emits nothing."""
    sch = star_schema(seed=51, n_fact=60, n_dim=8)
    cfg = BoostConfig(**CFG)
    ib = IncrementalBooster(sch, cfg)
    direct = Booster(sch, cfg)
    eng = ib.engine
    rng = np.random.default_rng(1)
    masks_cap, masks_n = {}, {}
    for t in sch.tables:
        cap, n = ib.state.capacity(t.name), t.n_rows
        m = np.ones((2, cap), bool)
        m[1, :] = rng.random(cap) < 0.6          # non-uniform second node
        masks_cap[t.name] = jnp.asarray(m)
        masks_n[t.name] = jnp.asarray(m[:, :n])  # initial slots ARE the rows
    out_m = np.asarray(eng.grouped_c3("fact", masks_cap))
    out_d = np.asarray(direct.engine.grouped_c3("fact", masks_n))
    n = sch.table("fact").n_rows
    np.testing.assert_allclose(out_m[:, :n], out_d, rtol=1e-5, atol=1e-5)
    assert not out_m[:, n:].any()                # dead slots stay ⊕-zero
    e0 = ib.counter.edges
    np.testing.assert_array_equal(
        np.asarray(eng.grouped_c3("fact", masks_cap)), out_m
    )
    assert ib.counter.edges == e0                # full cache hit


def test_engine_invalidation_is_table_local():
    """A delta on one dimension table must not retire cached messages of
    subtrees that don't contain it: the next family re-emits only edges
    on the dirty table's paths."""
    sch = star_schema(seed=52, n_fact=60, n_dim=8, n_dim_tables=3)
    cfg = BoostConfig(**CFG)
    ib = IncrementalBooster(sch, cfg)
    eng = ib.engine
    masks = {t.name: jnp.ones((1, ib.state.capacity(t.name)), jnp.bool_)
             for t in sch.tables}
    eng.grouped_c3("fact", masks)
    rng = np.random.default_rng(2)
    ib.apply([TableDelta("dim1", updates=(
        np.asarray([0, 1]),
        {c: rng.standard_normal(2).astype(np.float32)
         for c in sch.table("dim1").feature_columns},
    ))])
    e0 = ib.counter.edges
    eng.grouped_c3("fact", masks)
    # star grouped by fact: each dim's message is one edge; only dim1's
    # signature changed
    assert ib.counter.edges - e0 == 1
