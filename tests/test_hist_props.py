"""Property suite for histogram-binning invariants: the bin map is
monotone in the column value, delta-driven re-binning equals a fresh
re-bin against the same frozen edges bit-for-bit (host model AND
through the maintained engine), and the histogram sweep degenerates to
the exact sweep when every distinct value gets its own bin.

Hypothesis-driven when available (requirements-dev.txt); the seeded
deterministic sweeps exercise the same checkers so tier-1 keeps real
coverage when hypothesis is absent (tests/_hypothesis_compat.py makes
the @given tests skip cleanly)."""
import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # pragma: no cover
    from _hypothesis_compat import given, settings, st

from repro.core import BoostConfig, Schema, Table, quantile_cuts
from repro.core.hist import (
    TableHistPlan, bin_values, build_hist_plans, rebin_rows,
)
from repro.core.splits import best_split_for_table, build_split_plans
from repro.incremental import IncrementalBooster
from repro.relational.generators import delta_stream, star_schema


# ------------------------------------------------------------- checkers --

def _check_monotone(col, n_bins):
    cuts = quantile_cuts(col, n_bins)
    bins = bin_values(cuts, col, n_bins)
    finite = np.isfinite(col)
    assert (bins[~finite] == n_bins).all()           # invalid bin
    assert (bins[finite] < n_bins).all()
    order = np.argsort(col[finite], kind="stable")
    assert (np.diff(bins[finite][order]) >= 0).all()  # monotone in value
    # every cut is crossed: x >= cut ⟺ bin(x) > bin(largest value < cut)
    for j, c in enumerate(cuts):
        assert (bins[finite] > j).sum() == (col[finite] >= c).sum()


def _check_delta_rebin(base, updates, n_bins):
    """Re-binning updated rows in place must equal re-binning the whole
    final matrix against the SAME frozen edges, bit-for-bit."""
    rng_cols = base.shape[1]
    sch = Schema(
        [Table("t", {**{f"x{f}": base[:, f] for f in range(rng_cols)},
                     "y": np.zeros(len(base), np.float32)},
               feature_columns=tuple(f"x{f}" for f in range(rng_cols)))],
        label=("t", "y"),
    )
    plan = build_hist_plans(sch, n_bins=n_bins)["t"]
    final = base.copy()
    rows, vals = updates
    final[rows] = vals
    rebin_rows(plan, rows, vals)
    for f in range(rng_cols):
        expect = bin_values(plan.cuts[f, : plan.n_cuts[f]],
                            final[:, f], plan.n_bins)
        np.testing.assert_array_equal(plan.bins[f], expect)
    assert plan.rebinned_since_edges == len(rows)


def _check_degenerate(vals_pool, n, seed):
    """Small value pool ⇒ per-value bins ⇒ hist sweep == exact sweep.
    Node stats are small integers so per-candidate prefix sums are exact
    in f32 regardless of accumulation order — the routes' scores are
    then bitwise identical and the comparison can't flake on ulps."""
    rng = np.random.default_rng(seed)
    cols = {f"x{f}": rng.choice(vals_pool, n).astype(np.float32)
            for f in range(2)}
    cols["y"] = np.zeros(n, np.float32)
    sch = Schema([Table("t", cols, feature_columns=("x0", "x1"))],
                 label=("t", "y"))
    pe = build_split_plans(sch)["t"]
    ph = build_hist_plans(sch, n_bins=len(vals_pool) + 1)["t"]
    nn = jnp.asarray((rng.random((3, n)) < 0.7).astype(np.float32))
    ss = jnp.asarray(rng.integers(-3, 4, (3, n)).astype(np.float32)) * nn
    re = best_split_for_table(pe, nn, ss)
    rh = best_split_for_table(ph, nn, ss)
    np.testing.assert_array_equal(np.asarray(re.feature),
                                  np.asarray(rh.feature))
    np.testing.assert_array_equal(np.asarray(re.threshold),
                                  np.asarray(rh.threshold))
    np.testing.assert_allclose(np.asarray(re.score), np.asarray(rh.score),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ hypothesis --

@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.floats(-100, 100, width=32), min_size=1, max_size=80),
    st.integers(2, 20),
)
def test_bin_map_monotone_hypothesis(vals, n_bins):
    _check_monotone(np.asarray(vals, np.float32), n_bins)


@settings(max_examples=30, deadline=None)
@given(
    st.integers(0, 10 ** 6),
    st.integers(5, 40),
    st.integers(2, 12),
)
def test_delta_rebin_equals_fresh_hypothesis(seed, n, n_bins):
    rng = np.random.default_rng(seed)
    base = rng.standard_normal((n, 2)).astype(np.float32)
    k = int(rng.integers(1, n + 1))
    rows = rng.choice(n, size=k, replace=False)
    vals = rng.standard_normal((k, 2)).astype(np.float32)
    vals[rng.random(k) < 0.2] = np.inf               # deletions
    _check_delta_rebin(base, (rows, vals), n_bins)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(2, 9))
def test_hist_degenerates_to_exact_hypothesis(seed, n_vals):
    _check_degenerate(np.linspace(-1, 1, n_vals), 60, seed)


# -------------------------------------------------------- seeded fallback --

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_bin_map_monotone_seeded(seed):
    rng = np.random.default_rng(seed)
    col = rng.standard_normal(120).astype(np.float32)
    col[rng.random(120) < 0.1] = np.inf
    for n_bins in (2, 7, 32, 256):
        _check_monotone(col, n_bins)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_delta_rebin_equals_fresh_seeded(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 40))
    base = rng.standard_normal((n, 2)).astype(np.float32)
    k = int(rng.integers(1, n + 1))
    rows = rng.choice(n, size=k, replace=False)
    vals = rng.standard_normal((k, 2)).astype(np.float32)
    vals[rng.random(k) < 0.2] = np.inf
    _check_delta_rebin(base, (rows, vals), int(rng.integers(2, 12)))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_hist_degenerates_to_exact_seeded(seed):
    _check_degenerate(np.linspace(-1, 1, 3 + 2 * seed), 60, seed)


def test_rebin_capacity_growth_pads_invalid():
    """Row-domain growth puts new slots in the invalid bin until their
    values arrive — exactly where +inf dead padding belongs."""
    rng = np.random.default_rng(5)
    base = rng.standard_normal((10, 2)).astype(np.float32)
    sch = Schema(
        [Table("t", {"x0": base[:, 0], "x1": base[:, 1],
                     "y": np.zeros(10, np.float32)},
               feature_columns=("x0", "x1"))],
        label=("t", "y"),
    )
    plan = build_hist_plans(sch, n_bins=8)["t"]
    rebin_rows(plan, np.asarray([12]),
               np.asarray([[0.0, 0.0]], np.float32), n_rows=16)
    assert plan.n_rows == 16
    assert (plan.bins[:, 10:12] == plan.n_bins).all()
    assert (plan.bins[:, 13:] == plan.n_bins).all()
    assert (plan.bins[:, 12] < plan.n_bins).all()


def test_maintained_plans_track_store_through_delta_stream():
    """Integration model-check: after an arbitrary churn stream with
    frozen edges (huge tolerance), every maintained plan's bin map
    equals a fresh re-bin of the engine's current capacity featmat
    against those same edges, bit-for-bit — and only touched rows were
    ever re-binned (o(n) maintenance)."""
    sch = star_schema(seed=41, n_fact=90, n_dim=10)
    cfg = BoostConfig(n_trees=1, depth=2, mode="sketch", ssr_mode="off",
                      split_mode="hist", hist_bins=16, hist_edge_tol=1e9)
    ib = IncrementalBooster(sch, cfg)
    ib.fit()
    for batch in delta_stream(sch, ib.live_rows, seed=43, n_batches=4,
                              ops_per_batch=5):
        ib.apply(batch)
        ib.booster.refresh_plans()
    fms = ib.engine.plan_featmats()
    for name, plan in ib.booster.plans.items():
        assert isinstance(plan, TableHistPlan)
        fm = fms[name]
        assert plan.n_rows == fm.shape[0]
        for f in range(plan.bins.shape[0]):
            expect = bin_values(plan.cuts[f, : plan.n_cuts[f]],
                                fm[:, f], plan.n_bins)
            np.testing.assert_array_equal(plan.bins[f], expect)
        assert plan.rebinned_since_edges < plan.n_rows
