"""Observability layer: metrics algebra, span semantics, exports, and
the guarantees the instrumented hot paths rely on — disabled-mode spans
are free and tracing never changes what training computes.
"""
from __future__ import annotations

import importlib.util
import json
import math
import os
import threading
import time

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import BoostConfig, Booster, Channels, QueryCounter, SumProd
from repro.obs import (
    BenchReport, Histogram, MetricsRegistry, diff_snapshots,
    disable_tracing, enable_tracing, format_summary_table, get_registry,
    get_tracer, merge_snapshots, span, validate_bench,
)
from repro.serving.service import ServiceStats
from repro.relational.generators import star_schema

# bucket grid: RES sub-buckets per octave → any quantile is within one
# bucket (~2^(1/8)−1 ≈ 9% relative) of the empirical value
BUCKET_REL = 2 ** (1 / Histogram.RES) - 1


@pytest.fixture(autouse=True)
def _tracing_off():
    """Every test starts and ends with tracing disabled (process-global)."""
    disable_tracing()
    yield
    disable_tracing()


# ------------------------------------------------------------------ metrics --

def test_histogram_quantiles_track_numpy():
    rng = np.random.default_rng(0)
    draws = rng.lognormal(mean=1.0, sigma=1.2, size=5000)
    h = Histogram("t")
    for v in draws:
        h.observe(v)
    for q in (0.50, 0.90, 0.99):
        want = float(np.quantile(draws, q))
        got = h.quantile(q)
        assert abs(got - want) / want <= 2 * BUCKET_REL, (q, got, want)
    s = h.summary()
    assert s["count"] == len(draws)
    assert s["min"] == pytest.approx(draws.min())
    assert s["max"] == pytest.approx(draws.max())
    assert s["mean"] == pytest.approx(draws.mean())


def test_histogram_nonpositive_underflow():
    h = Histogram()
    for v in (-1.0, 0.0, 2.0, 4.0):
        h.observe(v)
    assert h.count == 4 and h.min == -1.0
    assert h.quantile(0.0) == -1.0          # underflow bucket reports min
    assert h.quantile(1.0) == 4.0


def test_histogram_merge_is_exact():
    rng = np.random.default_rng(1)
    a, b, both = Histogram(), Histogram(), Histogram()
    for i, v in enumerate(rng.lognormal(size=2000)):
        (a if i % 2 else b).observe(v)
        both.observe(v)
    a.merge(b)
    assert a.buckets == both.buckets
    assert a.count == both.count and a.sum == pytest.approx(both.sum)
    assert a.quantile(0.9) == both.quantile(0.9)


def test_snapshot_diff_and_merge():
    reg = MetricsRegistry()
    reg.counter("c").inc(5)
    reg.gauge("g").set(2.5)
    h = reg.histogram("h")
    h.observe(1.0)
    before = reg.snapshot()
    reg.counter("c").inc(3)
    h.observe(8.0)
    h.observe(8.0)
    after = reg.snapshot()
    d = diff_snapshots(before, after)
    assert d["c"]["value"] == 3
    assert d["h"]["count"] == 2 and d["h"]["mean"] == pytest.approx(8.0)
    # the window's quantiles come from the differenced buckets: ~8, not 1
    assert d["h"]["p50"] == pytest.approx(8.0, rel=2 * BUCKET_REL)
    m = merge_snapshots(before, d)
    assert m["c"]["value"] == after["c"]["value"]
    assert m["h"]["count"] == after["h"]["count"]
    table = format_summary_table(after, title="t")
    assert "c" in table and "p99" in table


def test_registry_type_conflict():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.histogram("x")


# -------------------------------------------------------------------- spans --

def test_span_nesting_depth_and_rollup():
    tr = enable_tracing()
    with span("outer", k=1):
        with span("inner"):
            pass
        with span("inner"):
            pass
    disable_tracing()
    evs = {((e["name"]), e["depth"]) for e in tr.events}
    assert ("outer", 0) in evs and ("inner", 1) in evs
    outer = next(e for e in tr.events if e["name"] == "outer")
    assert outer["k"] == 1 and outer["dur_ms"] >= 0
    roll = tr.rollup()
    assert roll["inner"]["count"] == 2 and roll["outer"]["count"] == 1


def test_span_exception_safety_with_duplicate_names():
    tr = enable_tracing()
    with pytest.raises(RuntimeError):
        with span("same"):
            with span("same"):
                raise RuntimeError("boom")
    # both frames popped despite the exception; a fresh span sits at depth 0
    with span("after"):
        pass
    disable_tracing()
    errs = [e for e in tr.events if e.get("error")]
    assert len(errs) == 2 and all(e["error"] == "RuntimeError" for e in errs)
    assert next(e for e in tr.events if e["name"] == "after")["depth"] == 0


def test_disabled_span_is_shared_noop():
    assert span("a", x=1) is span("b")          # no allocation when off
    n = 100_000
    t0 = time.perf_counter()
    for _ in range(n):
        with span("hot", i=0):
            pass
    dt = time.perf_counter() - t0
    # generous CI bound — the real figure is tens of ns per span
    assert dt / n < 20e-6, f"{dt / n * 1e9:.0f}ns per disabled span"


def test_chrome_trace_roundtrip(tmp_path):
    tr = enable_tracing()
    with span("phase", rows=3):
        with span("step"):
            pass
    disable_tracing()
    p = tmp_path / "trace.json"
    n = tr.dump_chrome_trace(str(p))
    doc = json.loads(p.read_text())
    assert len(doc["traceEvents"]) == n == 2
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X" and ev["pid"] == 1
        assert isinstance(ev["ts"], (int, float)) and ev["dur"] >= 0
    names = {ev["name"] for ev in doc["traceEvents"]}
    assert names == {"phase", "step"}
    phase = next(e for e in doc["traceEvents"] if e["name"] == "phase")
    assert phase["args"]["rows"] == 3

    jl = tmp_path / "trace.jsonl"
    assert tr.dump_jsonl(str(jl)) == 2
    lines = [json.loads(x) for x in jl.read_text().splitlines()]
    assert {e["name"] for e in lines} == {"phase", "step"}


def test_span_threads_do_not_share_stacks():
    tr = enable_tracing()

    def work(i):
        with span("t", i=i):
            time.sleep(0.001)
            with span("u", i=i):
                pass

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    disable_tracing()
    us = [e for e in tr.events if e["name"] == "u"]
    assert len(us) == 4 and all(e["depth"] == 1 for e in us)


# ----------------------------------------------------- QueryCounter shim --

def test_query_counter_thread_safe_and_mirrored():
    g = get_registry().counter("sumprod.edges")
    g0 = g.value
    c = QueryCounter()

    def work():
        for _ in range(1000):
            c.bump()
            c.bump_edges(2)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.count == 8000 and c.edges == 16000
    assert g.value - g0 == 16000            # global mirror sees the same work


def test_query_counter_per_instance_isolation():
    a, b = QueryCounter(), QueryCounter()
    a.bump_edges(5)
    assert (a.edges, b.edges) == (5, 0)     # the IVM benchmark ratios


def test_edge_accounting_unchanged(star):
    """Regression pin: one inside-out pass still bumps exactly one
    segment-⊕ emission per join-tree edge, per counter instance."""
    sch = star[0]
    c = QueryCounter()
    sp = SumProd(sch, counter=c)
    sem = Channels(2)
    fac = sp.ones_factors(sem)
    lbl = sch.labels
    fac[sch.label_table] = jnp.stack([jnp.ones_like(lbl), lbl], -1)
    e0, q0 = c.edges, c.count
    sp(sem, fac, group_by=sch.label_table)
    n_edges = len(sch.tables) - 1           # rooted join tree: τ − 1 edges
    assert c.edges - e0 == n_edges
    assert c.count - q0 == 1


# -------------------------------------------- tracing is observation-only --

def test_tracing_does_not_change_trained_trees():
    sch = star_schema(seed=11, n_fact=120, n_dim=12)
    cfg = BoostConfig(n_trees=2, depth=2, mode="sketch", ssr_mode="off")
    plain, _ = Booster(sch, cfg).fit()
    enable_tracing()
    traced, _ = Booster(sch, cfg).fit()
    tr = disable_tracing()
    assert len(tr.events) > 0               # instrumentation actually fired
    for a, b in zip(plain, traced):
        assert np.array_equal(np.asarray(a.feat), np.asarray(b.feat))
        assert np.array_equal(np.asarray(a.thr), np.asarray(b.thr))
        assert np.array_equal(np.asarray(a.leaf), np.asarray(b.leaf))
    names = {e["name"] for e in tr.events}
    assert {"boost.round", "boost.sweep", "sumprod.emit"} <= names


# ------------------------------------------------------- service metrics --

def test_service_stats_snapshot_quantiles():
    st = ServiceStats()
    lats = [float(v) for v in range(1, 101)]    # 1..99ms plus one 100ms tail
    for ms in lats:
        st.latency_ms.observe(ms)
        st.queue_wait_ms.observe(ms / 10)
        st._requests.inc()
    snap = st.snapshot()
    assert snap["requests"] == 100
    assert snap["latency_ms"]["count"] == 100
    assert snap["latency_ms"]["p99"] == pytest.approx(
        float(np.quantile(lats, 0.99)), rel=2 * BUCKET_REL)
    assert snap["queue_wait_ms"]["p50"] < snap["latency_ms"]["p50"]


# ------------------------------------------------------------ BENCH files --

def _load_report_module():
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "benchmarks", "report.py")
    spec = importlib.util.spec_from_file_location("bench_report", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_report_write_and_validate(tmp_path):
    rep = BenchReport("demo", config={"smoke": True})
    rep.add_rows([{"bench": "D1", "x": 1}])
    rep.set_metric("ratio", 3.5)
    path = rep.write(str(tmp_path))
    doc = json.loads(open(path).read())
    assert validate_bench(doc) == []
    assert doc["schema_version"] == 1 and doc["metrics"]["ratio"] == 3.5
    assert validate_bench({"schema_version": 2}) != []


def test_report_check_gate(tmp_path):
    mod = _load_report_module()
    rep = BenchReport("demo")
    rep.add_rows([{"bench": "D1"}])
    rep.set_metric("ratio", 4.0)
    rep.set_metric("err", 0.1)
    rep.write(str(tmp_path))
    baselines = tmp_path / "baselines.json"

    def gate(pins):
        baselines.write_text(json.dumps({"demo": pins}))
        return mod.check(mod.load_benches(str(tmp_path)), str(baselines))

    assert gate({"ratio": {"pin": 4.0, "kind": "min"}}) == []
    assert gate({"ratio": {"pin": 4.0, "kind": "min"},
                 "err": {"pin": 0.1, "kind": "max"}}) == []
    # >2× regressions trip; within-2× drift does not
    assert gate({"ratio": {"pin": 9.0, "kind": "min"}})      # 4 < 9/2
    assert gate({"ratio": {"pin": 7.0, "kind": "min"}}) == []
    assert gate({"err": {"pin": 0.04, "kind": "max"}})       # 0.1 > 0.08
    assert gate({"missing": {"pin": 1.0, "kind": "min"}})
    baselines.write_text(json.dumps({"absent": {"m": {"pin": 1, "kind": "min"}}}))
    assert mod.check(mod.load_benches(str(tmp_path)), str(baselines))
