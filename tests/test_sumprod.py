"""Inside-out SumProd vs brute force over the materialized join —
including a hypothesis sweep over random acyclic schemas and multiple
semirings (the engine must be semiring-generic: Lemma 1.1)."""
import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core import (
    Arithmetic, BooleanSR, Channels, NotAcyclicError, Schema, SumProd, Table,
    Tropical, materialize_join,
)


def _check_all_semirings(sch):
    sp = SumProd(sch)
    J = materialize_join(sch)
    y = np.asarray(J[sch.label_column])
    nJ = len(y)

    # counting
    a = Arithmetic()
    assert int(sp(a, sp.ones_factors(a))) == nJ

    if nJ == 0:
        return

    # fused (1, y, y²) channels
    c3 = Channels(3)
    f = sp.ones_factors(c3)
    lbl = sch.labels
    f[sch.label_table] = jnp.stack([jnp.ones_like(lbl), lbl, lbl ** 2], -1)
    out = np.asarray(sp(c3, f))
    np.testing.assert_allclose(out, [nJ, y.sum(), (y ** 2).sum()], rtol=1e-4, atol=1e-4)

    # grouped by every table == bincount brute force
    for t in sch.tables:
        g = np.asarray(sp(c3, f, group_by=t.name))
        rows = np.asarray(J["__rows__" + t.name])
        np.testing.assert_allclose(
            g[:, 0], np.bincount(rows, minlength=t.n_rows), rtol=1e-4
        )
        np.testing.assert_allclose(
            g[:, 1], np.bincount(rows, weights=y, minlength=t.n_rows),
            rtol=1e-3, atol=1e-3,
        )

    # tropical: min over join rows of Σ per-table weights
    tr = Tropical()
    rng = np.random.default_rng(0)
    ftr = {
        t.name: jnp.asarray(rng.standard_normal(t.n_rows), jnp.float32)
        for t in sch.tables
    }
    w = sum(
        np.asarray(ftr[t.name])[np.asarray(J["__rows__" + t.name])]
        for t in sch.tables
    )
    assert abs(float(sp(tr, ftr)) - w.min()) < 1e-4

    # boolean: non-emptiness
    b = BooleanSR()
    assert bool(sp(b, sp.ones_factors(b))) == (nJ > 0)


def test_star(star):
    _check_all_semirings(star[0])


def test_chain(chain):
    _check_all_semirings(chain[0])


def test_cyclic_raises():
    # triangle R(a,b), S(b,c), T(c,a) is the canonical cyclic join
    mk = lambda n, c1, c2: Table(
        name=n,
        columns={c1: np.arange(4, dtype=np.int64), c2: np.arange(4, dtype=np.int64)},
    )
    with pytest.raises(NotAcyclicError):
        Schema([mk("R", "a", "b"), mk("S", "b", "c"), mk("T", "c", "a")], label=("R", "a"))


@st.composite
def random_acyclic_schema(draw):
    """Random join *tree* over τ tables (trees are always acyclic)."""
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31 - 1)))
    tau = draw(st.integers(2, 4))
    tables = []
    for i in range(tau):
        n = draw(st.integers(2, 10))
        cols = {}
        if i > 0:
            parent = int(rng.integers(0, i))
            key = f"k{parent}_{i}"
            dom = draw(st.integers(1, 4))
            cols[key] = rng.integers(0, dom, n).astype(np.int64)
            # parent must carry the key too
            pt = tables[parent]
            pt.columns[key] = rng.integers(0, dom, pt.n_rows).astype(np.int64)
        cols[f"f{i}"] = rng.standard_normal(n).astype(np.float32)
        tables.append(Table(name=f"t{i}", columns=cols))
    tables = [Table(name=t.name, columns=t.columns) for t in tables]  # re-derive features
    return Schema(tables, label=("t0", "f0"))


@settings(max_examples=12, deadline=None)
@given(random_acyclic_schema())
def test_random_acyclic_schemas(sch):
    _check_all_semirings(sch)
