"""Graceful fallback when `hypothesis` is not installed (it is a dev
dependency — see requirements-dev.txt).  Property-based tests skip with a
clear reason instead of killing collection for the whole module; every
example-based test in the same file still runs.

Usage (in test modules):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                       # pragma: no cover
        from _hypothesis_compat import given, settings, st
"""
import pytest

_REASON = "hypothesis not installed (pip install -r requirements-dev.txt)"


class _Strategy:
    """Inert stand-in for hypothesis strategies (never drawn from)."""

    def __call__(self, *a, **k):
        return self

    def __getattr__(self, name):
        return _Strategy()


class _Strategies:
    def composite(self, fn):
        return _Strategy()

    def __getattr__(self, name):
        return _Strategy()


st = _Strategies()


def given(*_strategies, **_kw):
    def deco(fn):
        # NOTE: no functools.wraps — pytest would introspect the wrapped
        # signature and try to resolve the strategy args as fixtures
        def skipper():
            pytest.skip(_REASON)

        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper

    return deco


def settings(*_a, **_k):
    def deco(fn):
        return fn

    return deco
