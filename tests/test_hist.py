"""Quantile-histogram split search: differential equivalence with the
exact sweep (bit-identical trees when every distinct value gets its own
bin; quality parity under quantile subsampling), capacity-padding
semantics (+inf dead slots bin invalid and never become thresholds, on
both direct and maintained engines), and fresh-fit/route agreement."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    BoostConfig, Booster, Schema, Table, build_hist_plans, materialize_join,
    predict_rows, quantile_cuts,
)
from repro.core.hist import hist_scores
from repro.core.splits import best_split_for_table, build_split_plans
from repro.incremental import IncrementalBooster, TableDelta
from repro.relational.generators import star_schema

HIST = dict(split_mode="hist", hist_bins=64)


def _assert_trees_match(a, b, thr_exact=False):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x.feat), np.asarray(y.feat))
        if thr_exact:
            np.testing.assert_array_equal(np.asarray(x.thr), np.asarray(y.thr))
        else:
            np.testing.assert_allclose(np.asarray(x.thr), np.asarray(y.thr),
                                       rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(x.leaf), np.asarray(y.leaf),
                                   rtol=1e-4, atol=1e-5)


def _discrete_schema(seed=7, n=200, n_vals=13):
    """Low-cardinality float features: every distinct value fits in a
    small bin budget, the regime where hist must equal exact."""
    rng = np.random.default_rng(seed)
    cols = {"k": rng.integers(0, 10, n).astype(np.int64)}
    for f in range(3):
        cols[f"x{f}"] = rng.choice(
            np.linspace(-2, 2, n_vals), n).astype(np.float32)
    cols["y"] = (cols["x0"] + np.where(cols["x1"] >= 0, 2.0, -1.0)
                 + 0.1 * rng.standard_normal(n)).astype(np.float32)
    dim = {"k": np.arange(10, dtype=np.int64),
           "d0": rng.choice(np.linspace(-1, 1, 7), 10).astype(np.float32)}
    return Schema(
        [Table("fact", cols, feature_columns=("x0", "x1", "x2")),
         Table("dim", dim, feature_columns=("d0",))],
        label=("fact", "y"),
    )


# ------------------------------------------------------------ equivalence --

def test_hist_degenerates_to_exact_when_bins_cover_distinct():
    """B ≥ #distinct values per column ⇒ the cut set equals the exact
    sweep's candidates and the fitted trees are identical (features and
    thresholds bit-for-bit — both draw thresholds from the data)."""
    sch = _discrete_schema()
    base = dict(n_trees=3, depth=3, mode="sketch", ssr_mode="off")
    te, _ = Booster(sch, BoostConfig(**base)).fit()
    th, _ = Booster(sch, BoostConfig(**base, split_mode="hist",
                                     hist_bins=16)).fit()
    _assert_trees_match(te, th, thr_exact=True)


def test_hist_quality_parity_on_continuous_features():
    """Quantile subsampling (B ≪ n distinct values) may pick different
    splits, but model quality must stay within the 5%-of-variance
    parity band of the exact sweep."""
    sch = star_schema(seed=5, n_fact=300, n_dim=24)
    base = dict(n_trees=3, depth=2, mode="sketch", ssr_mode="off")
    te, _ = Booster(sch, BoostConfig(**base)).fit()
    th, _ = Booster(sch, BoostConfig(**base, split_mode="hist",
                                     hist_bins=32)).fit()
    J = materialize_join(sch)
    X = jnp.stack([J[c] for (_, c) in sch.features], axis=1)
    y = np.asarray(J[sch.label_column])
    mse_e = float(np.mean((y - np.asarray(predict_rows(te, X))) ** 2))
    mse_h = float(np.mean((y - np.asarray(predict_rows(th, X))) ** 2))
    var = float(np.var(y))
    assert (mse_h - mse_e) / var <= 0.05, (mse_h, mse_e, var)
    assert mse_h < 0.5 * var


def test_hist_accumulation_routes_agree():
    """The padded-gather route (CPU default) and the segment-⊕ scatter
    route (kernels/segment_sum) build the same histograms — per-table
    sweep outputs agree within f32 reduction-order noise."""
    sch = star_schema(seed=11, n_fact=400, n_dim=16)
    plans = build_hist_plans(sch, n_bins=32)
    rng = np.random.default_rng(3)
    for name, plan in plans.items():
        rows = plan.n_rows
        n = jnp.asarray(rng.random((4, rows)).astype(np.float32))
        s = jnp.asarray(rng.standard_normal((4, rows)).astype(np.float32))
        tot_n, tot_s = jnp.sum(n, axis=1), jnp.sum(s, axis=1)
        g = hist_scores(plan, n, s, tot_n, tot_s, route="gather")
        sc = hist_scores(plan, n, s, tot_n, tot_s, route="scatter")
        for a, b in zip(g, sc):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)


def test_split_mode_validated():
    sch = _discrete_schema()
    with pytest.raises(ValueError, match="split_mode"):
        Booster(sch, BoostConfig(split_mode="histo"))


# ------------------------------------------------- capacity-pad semantics --

def test_dead_slot_padding_bins_invalid_and_never_thresholds():
    """Regression (direct-engine side): +inf dead rows in a
    capacity-shaped featmat override must land in the invalid bin, stay
    out of the quantile edges, and never be chosen as thresholds."""
    sch = _discrete_schema(seed=19)
    featmats = {}
    for t in sch.tables:
        fm = np.asarray(sch.featmat[t.name]).copy()
        pad = np.full((7, fm.shape[1]), np.inf, np.float32)
        featmats[t.name] = np.concatenate([fm, pad])
    plans = build_hist_plans(sch, featmats=featmats, n_bins=16)
    for name, plan in plans.items():
        assert (plan.bins[:, -7:] == plan.n_bins).all()      # invalid bin
        real = plan.cuts[plan.cuts < np.inf]
        assert np.isfinite(real).all()                       # edges finite
        # a sweep with uniform stats over ALL slots (dead included) must
        # still return finite thresholds wherever a split exists
        rows = plan.n_rows
        n = jnp.ones((2, rows), jnp.float32)
        s = jnp.asarray(
            np.tile(np.linspace(-1, 1, rows, dtype=np.float32), (2, 1)))
        res = best_split_for_table(plan, n, s)
        assert np.isfinite(np.asarray(res.threshold)).all(), name


def test_maintained_engine_dead_slots_after_deletes():
    """Regression (maintained-engine side): after deletes the freed
    slots' stale feature bytes sit at +inf in the plan featmats — they
    re-bin invalid, and every split the refit selects keeps a finite
    threshold (dead TREE nodes legitimately carry thr=+inf; live splits
    never do)."""
    sch = star_schema(seed=23, n_fact=80, n_dim=8)
    cfg = BoostConfig(n_trees=2, depth=2, mode="sketch", ssr_mode="off",
                      **HIST)
    ib = IncrementalBooster(sch, cfg)
    ib.fit()
    live = ib.live_rows("fact")
    ib.apply([TableDelta("fact", deletes=live[:10])])
    rep = ib.refit(n_new_trees=2, drift_threshold=-np.inf)
    assert rep.refitted
    for name, plan in ib.booster.plans.items():
        dead = ~ib.state.tables[name].live
        assert (plan.bins[:, dead] == plan.n_bins).all(), name
    for t in ib.trees:
        feat, thr = np.asarray(t.feat), np.asarray(t.thr)
        assert np.isfinite(thr[feat >= 0]).all()


# ----------------------------------------------------------------- units --

def test_quantile_cuts_properties():
    rng = np.random.default_rng(0)
    col = np.concatenate([rng.standard_normal(500).astype(np.float32),
                          np.full(9, np.inf, np.float32)])
    for B in (4, 16, 64):
        cuts = quantile_cuts(col, B)
        assert len(cuts) <= B - 1
        assert np.isfinite(cuts).all()                  # +inf never a cut
        assert (np.diff(cuts) > 0).all()                # distinct, ascending
        finite = col[np.isfinite(col)]
        assert np.isin(cuts, finite).all()              # cuts are data values
        assert cuts.min() > finite.min()                # min can't be a cut
    # low cardinality: every distinct value except the min becomes a cut
    small = np.asarray([3.0, 1.0, 2.0, 1.0, 3.0], np.float32)
    np.testing.assert_array_equal(quantile_cuts(small, 8),
                                  np.asarray([2.0, 3.0], np.float32))


def test_exact_sweep_feature_chunking_is_invisible(monkeypatch):
    """The vectorized exact sweep blocks the feature axis when the
    batched intermediates would exceed the memory budget; per-feature
    results are independent, so a forced tiny block must reproduce the
    single-block result bit-for-bit."""
    from repro.core import splits as splits_mod

    sch = star_schema(seed=37, n_fact=200, n_dim=16)
    plans = build_split_plans(sch)
    rng = np.random.default_rng(2)
    for name, plan in plans.items():
        rows = plan.order.shape[1]
        n = jnp.asarray((rng.random((3, rows)) < 0.8).astype(np.float32))
        s = jnp.asarray(rng.standard_normal((3, rows)).astype(np.float32)) * n
        full = best_split_for_table(plan, n, s)
        monkeypatch.setattr(splits_mod, "_EXACT_BLOCK_ELEMS", 3 * rows)
        chunked = best_split_for_table(plan, n, s)
        monkeypatch.undo()
        for f in ("score", "feature", "threshold", "left_sum", "left_cnt",
                  "right_sum", "right_cnt"):
            np.testing.assert_array_equal(np.asarray(getattr(full, f)),
                                          np.asarray(getattr(chunked, f)))


def test_hist_plan_matches_exact_candidates_small():
    """With per-value bins the hist sweep and the exact sweep score the
    same candidate set — spot-check SplitResult equality on random node
    stats (not just end-to-end trees)."""
    sch = _discrete_schema(seed=29)
    pe = build_split_plans(sch)
    ph = build_hist_plans(sch, n_bins=16)
    rng = np.random.default_rng(1)
    for name in pe:
        rows = pe[name].order.shape[1]
        n = jnp.asarray((rng.random((3, rows)) < 0.8).astype(np.float32))
        s = jnp.asarray(rng.standard_normal((3, rows)).astype(np.float32)) * n
        re = best_split_for_table(pe[name], n, s)
        rh = best_split_for_table(ph[name], n, s)
        np.testing.assert_array_equal(np.asarray(re.feature),
                                      np.asarray(rh.feature))
        np.testing.assert_array_equal(np.asarray(re.threshold),
                                      np.asarray(rh.threshold))
        np.testing.assert_allclose(np.asarray(re.score),
                                   np.asarray(rh.score), rtol=1e-4, atol=1e-4)
