"""Crash-recovery fault matrix: after ANY injected fault — a crash at
each durability point, a torn append, tail bit-rot, tail truncation, a
SIGKILL'd writer process — recovery lands on a valid LSN and the
recovered state scores bit-equal the recompute oracle at that
``data_version``.

Tier-1 runs the subprocess SIGKILL smoke plus one representative
in-process fault per family; the exhaustive crash-point matrix is
marked ``slow`` (nightly, ``pytest -m ""``).
"""
import os
import signal
import subprocess
import sys
import tempfile
import textwrap
import time

import numpy as np
import pytest

from _faultfs import CrashPoint, FaultPlan, flip_tail_bit, truncate_tail
from repro.core import Booster, BoostConfig
from repro.incremental import MaintainedScorer
from repro.incremental.recover import (
    latest_checkpoint_lsn, load_checkpoint, recover_scorer, recover_state,
    save_checkpoint,
)
from repro.incremental.wal import WalWriter, scan_wal, wal_path
from repro.relational.generators import delta_stream, star_schema
from repro.serving import compile_ensemble

SEED = 7


def _schema_and_trees():
    sch = star_schema(seed=SEED, n_fact=100, n_dim=10)
    b = Booster(sch, BoostConfig(n_trees=2, depth=2, mode="sketch",
                                 ssr_mode="off"))
    return sch, b.fit()[0]


@pytest.fixture(scope="module")
def model():
    return _schema_and_trees()


def _assert_recovered_matches_oracle(ms, root):
    tot, cnt = (np.asarray(a) for a in ms.score_grouped(root))
    ot, oc = (np.asarray(a) for a in ms.recompute_oracle(root))
    assert tot.tobytes() == ot.tobytes(), "recovered ≠ oracle (tot)"
    assert cnt.tobytes() == oc.tobytes(), "recovered ≠ oracle (cnt)"


def _stream_until_crash(model, wal_dir, plan, n_batches=8, ckpt_dir=None,
                        ckpt_at=None, sync_every=1):
    """Drive a WAL-attached writer until the plan kills it (or the
    stream ends).  Returns the writer-side versions that were applied
    before death."""
    sch, trees = model
    ms = MaintainedScorer(compile_ensemble(sch, trees))
    w = WalWriter(wal_dir, sync_every=sync_every, fault=plan)
    w.attach(ms.state)
    applied = 0
    try:
        for i, b in enumerate(delta_stream(sch, ms.live_rows, seed=3,
                                           n_batches=n_batches,
                                           ops_per_batch=4)):
            ms.apply(b)
            applied = ms.data_version
            if ckpt_at is not None and i + 1 == ckpt_at:
                save_checkpoint(ms.state, ckpt_dir, fault=plan)
    except CrashPoint:
        pass
    else:
        w.close()
    return applied


CRASH_POINTS = [
    ("append.before", None),
    ("append.write", 5),        # torn: 5 bytes of the record persisted
    ("append.write", 64),       # torn: most of the record persisted
    ("append.after", None),
    ("sync.before", None),
    ("sync.after", None),
]
CKPT_POINTS = ["ckpt.before_rename", "ckpt.after_rename", "ckpt.after"]


def _preserve_wal(wal_dir, tag):
    """Copy a failing fault's WAL dir for CI artifact upload."""
    art = os.environ.get("REPRO_WAL_ARTIFACT_DIR")
    if art:
        import shutil
        dst = os.path.join(art, tag.replace("/", "_").replace(".", "_"))
        shutil.rmtree(dst, ignore_errors=True)
        shutil.copytree(wal_dir, dst)


def _check_crash_point(model, point, tear, on_hit=3):
    sch, trees = model
    root = sch.tables[0].name
    with tempfile.TemporaryDirectory() as d:
        plan = FaultPlan(crash_at=point, on_hit=on_hit, tear=tear)
        applied = _stream_until_crash(model, d, plan)
        try:
            # recovery must land on a durable LSN no newer than what the
            # writer applied, and score bit-equal the oracle there
            ms2, rep = recover_scorer(compile_ensemble(sch, trees), d)
            assert 0 <= rep.recovered_lsn <= applied + 1
            assert ms2.data_version == rep.recovered_lsn
            _assert_recovered_matches_oracle(ms2, root)
            # the repaired log accepts a resumed writer at the recovered LSN
            w = WalWriter(d, sync_every=1, repair=True)
            assert w.last_lsn == rep.recovered_lsn
            w.attach(ms2.state)
            w.close()
        except Exception:
            _preserve_wal(d, f"{point}_tear{tear}_hit{on_hit}")
            raise


def test_crash_torn_append_recovers_to_oracle(model):
    """Tier-1 representative: writer dies mid-append leaving a torn
    record; recovery discards the tail and matches the oracle."""
    _check_crash_point(model, "append.write", tear=5)


def test_crash_at_sync_recovers_to_oracle(model):
    _check_crash_point(model, "sync.before", tear=None)


@pytest.mark.slow
@pytest.mark.parametrize("point,tear", CRASH_POINTS)
@pytest.mark.parametrize("on_hit", [1, 2, 4])
def test_crash_point_matrix(model, point, tear, on_hit):
    """Nightly: the exhaustive crash-point × timing matrix."""
    _check_crash_point(model, point, tear, on_hit=on_hit)


@pytest.mark.parametrize("point", CKPT_POINTS)
def test_crash_during_checkpoint(model, point):
    """Death at every checkpoint publication step leaves either the old
    or the new checkpoint fully usable — never a half-published one."""
    sch, trees = model
    root = sch.tables[0].name
    with tempfile.TemporaryDirectory() as wd, \
            tempfile.TemporaryDirectory() as cd:
        plan = FaultPlan(crash_at=point)
        ms = MaintainedScorer(compile_ensemble(sch, trees))
        w = WalWriter(wd, sync_every=1).attach(ms.state)
        batches = delta_stream(sch, ms.live_rows, seed=3, n_batches=6,
                               ops_per_batch=4)
        for b in batches:
            ms.apply(b)
        with pytest.raises(CrashPoint):
            save_checkpoint(ms.state, cd, fault=plan)
        w.close()
        st, lsn, skipped = load_checkpoint(sch, cd)
        if point == "ckpt.before_rename":
            assert st is None            # nothing published yet
        else:
            assert st is not None and lsn == ms.data_version
        ms2, rep = recover_scorer(compile_ensemble(sch, trees), wd, cd)
        assert rep.recovered_lsn == ms.data_version
        _assert_recovered_matches_oracle(ms2, root)


def test_bit_flip_in_tail_discarded(model):
    """Bit rot in the newest record: the checksum rejects it, recovery
    stops at the previous LSN and still matches the oracle."""
    sch, trees = model
    root = sch.tables[0].name
    with tempfile.TemporaryDirectory() as d:
        applied = _stream_until_crash(model, d, plan=None, n_batches=6)
        flip_tail_bit(wal_path(d), back=3)
        ms2, rep = recover_scorer(compile_ensemble(sch, trees), d)
        assert rep.recovered_lsn == applied - 1
        assert rep.tail_bytes_discarded > 0
        _assert_recovered_matches_oracle(ms2, root)


@pytest.mark.parametrize("cut", [1, 7, 200])
def test_truncated_tail_discarded(model, cut):
    """A lost tail sector (any size) rolls back to the last complete
    record; recovery matches the oracle there."""
    sch, trees = model
    root = sch.tables[0].name
    with tempfile.TemporaryDirectory() as d:
        applied = _stream_until_crash(model, d, plan=None, n_batches=6)
        truncate_tail(wal_path(d), cut)
        ms2, rep = recover_scorer(compile_ensemble(sch, trees), d)
        assert rep.recovered_lsn < applied
        _assert_recovered_matches_oracle(ms2, root)


def test_corrupt_checkpoint_falls_back_to_older(model):
    """A bit-rotted newest checkpoint is skipped; recovery loads the
    previous one and replays a longer tail to the same final LSN."""
    sch, trees = model
    root = sch.tables[0].name
    with tempfile.TemporaryDirectory() as wd, \
            tempfile.TemporaryDirectory() as cd:
        ms = MaintainedScorer(compile_ensemble(sch, trees))
        w = WalWriter(wd, sync_every=1).attach(ms.state)
        for i, b in enumerate(delta_stream(sch, ms.live_rows, seed=3,
                                           n_batches=6, ops_per_batch=4)):
            ms.apply(b)
            if i in (1, 3):
                save_checkpoint(ms.state, cd)
        w.close()
        newest = latest_checkpoint_lsn(cd)
        # rot one data file of the newest checkpoint
        ck = os.path.join(cd, f"ckpt_{newest}")
        victim = next(p for p in sorted(os.listdir(ck)) if p.endswith(".npy"))
        flip_tail_bit(os.path.join(ck, victim), back=5)
        ms2, rep = recover_scorer(compile_ensemble(sch, trees), wd, cd)
        assert rep.checkpoints_skipped == 1
        assert rep.checkpoint_lsn < newest
        assert rep.recovered_lsn == ms.data_version
        _assert_recovered_matches_oracle(ms2, root)


# ----------------------------------------------------- subprocess SIGKILL --

_WRITER_SCRIPT = textwrap.dedent("""
    import sys
    from repro.incremental.state import DynamicState
    from repro.incremental.wal import WalWriter
    from repro.relational.generators import delta_stream, star_schema

    wal_dir = sys.argv[1]
    sch = star_schema(seed={seed}, n_fact=100, n_dim=10)
    state = DynamicState(sch)
    WalWriter(wal_dir, sync_every=1).attach(state)
    for batch in delta_stream(sch, state.live_rows, seed=3,
                              n_batches=100000, ops_per_batch=4):
        state.apply(batch)
""").format(seed=SEED)


def test_sigkill_writer_mid_stream_recovers_to_oracle(model, tmp_path):
    """The end-to-end crash smoke: a separate writer process is
    SIGKILL'd mid-stream (no cleanup, no atexit — exactly a crash);
    recovery in this process replays its log and bit-equals the oracle
    at the recovered version."""
    sch, trees = model
    root = sch.tables[0].name
    wal_dir = str(tmp_path / "wal")
    script = tmp_path / "writer.py"
    script.write_text(_WRITER_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.abspath("src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.Popen([sys.executable, str(script), wal_dir], env=env,
                            stdout=subprocess.DEVNULL,
                            stderr=subprocess.PIPE)
    try:
        deadline = time.time() + 120
        path = wal_path(wal_dir)
        while time.time() < deadline:
            if proc.poll() is not None:
                raise AssertionError(
                    "writer exited early:\n"
                    + proc.stderr.read().decode(errors="replace"))
            try:
                last, _, _ = scan_wal(path)
            except Exception:
                last = 0
            if last >= 20:
                break
            time.sleep(0.05)
        else:
            raise AssertionError("writer produced <20 LSNs in 120s")
    finally:
        if proc.poll() is None:
            proc.send_signal(signal.SIGKILL)
        proc.wait()

    # artifact for CI upload on failure (see .github/workflows)
    art = os.environ.get("REPRO_WAL_ARTIFACT_DIR")
    if art:
        import shutil
        os.makedirs(art, exist_ok=True)
        shutil.copy(path, os.path.join(art, "sigkill_wal.log"))

    last, valid_end, size = scan_wal(path)
    assert last >= 20
    ms2, rep = recover_scorer(compile_ensemble(sch, trees), wal_dir)
    assert rep.recovered_lsn == last
    _assert_recovered_matches_oracle(ms2, root)

    # the recovered store equals a state-only replay of the same log
    st, rep2 = recover_state(sch, wal_dir)
    assert rep2.recovered_lsn == rep.recovered_lsn
    for t, dt in st.tables.items():
        ours = ms2.state.tables[t]
        assert np.array_equal(dt.live, ours.live)
        for c, v in dt.columns.items():
            assert v.tobytes() == ours.columns[c].tobytes()
