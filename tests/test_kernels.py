"""Pallas kernels vs their pure-jnp oracles (interpret=True on CPU;
BlockSpec tiling identical to the TPU target).  Shape × dtype sweeps per
the assignment."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.sketch import Hash2


@pytest.mark.parametrize("B,k", [(4, 64), (32, 128), (7, 256), (128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_poly_mul(B, k, dtype):
    from repro.kernels.polymul.ops import poly_mul_op, poly_mul_ref

    rng = np.random.default_rng(B * k)
    a = jnp.asarray(rng.standard_normal((B, k)), dtype)
    b = jnp.asarray(rng.standard_normal((B, k)), dtype)
    got = poly_mul_op(a, b)
    want = poly_mul_ref(a.astype(jnp.float32), b.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), atol=tol * k ** 0.5, rtol=tol
    )


def test_poly_mul_is_semiring_product():
    """Kernel ⊗ must agree with the PolyCoeff semiring the trainer uses."""
    from repro.core.semiring import PolyCoeff
    from repro.kernels.polymul.ops import poly_mul_op

    sem = PolyCoeff(64)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((5, 64)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((5, 64)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(poly_mul_op(a, b)), np.asarray(sem.mul(a, b)), atol=1e-4
    )


@pytest.mark.parametrize("n,k", [(100, 16), (1000, 64), (5000, 256), (512, 128)])
def test_count_sketch(n, k):
    from repro.kernels.count_sketch.ops import count_sketch_op
    from repro.kernels.count_sketch.ref import count_sketch_ref

    rng = np.random.default_rng(n + k)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    h = Hash2.make(jax.random.PRNGKey(3), k)
    got = count_sketch_op(x, h)
    idx = jnp.arange(n)
    want = count_sketch_ref(x, h.bucket(idx), h.sign(idx), k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("n,keys,c", [(100, 16, 8), (1000, 64, 32), (513, 40, 1)])
def test_segment_sum(n, keys, c):
    from repro.kernels.segment_sum.ops import segment_sum_op
    from repro.kernels.segment_sum.ref import segment_sum_ref

    rng = np.random.default_rng(n + keys)
    vals = jnp.asarray(rng.standard_normal((n, c)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, keys, n), jnp.int32)
    got = segment_sum_op(vals, ids, keys)
    want = segment_sum_ref(vals, ids, keys)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    # 1-D (Arithmetic semiring) layout
    got1 = segment_sum_op(vals[:, 0], ids, keys)
    np.testing.assert_allclose(np.asarray(got1), np.asarray(want)[:, 0], atol=1e-4)


def test_segment_sum_is_semiring_segment_add():
    """Kernel-routed Channels.segment_add == the stock semiring op."""
    from repro.serving import KernelChannels
    from repro.core.semiring import Channels

    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.standard_normal((200, 12)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 9, 200), jnp.int32)
    got = KernelChannels(12).segment_add(vals, ids, 9)
    want = Channels(12).segment_add(vals, ids, 9)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("S,dh,causal", [(128, 64, True), (256, 128, True),
                                         (128, 64, False), (96, 32, True)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(S, dh, causal, dtype):
    from repro.kernels.flash_attention.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref

    rng = np.random.default_rng(S + dh)
    BH = 3
    q = jnp.asarray(rng.standard_normal((BH, S, dh)), dtype)
    k = jnp.asarray(rng.standard_normal((BH, S, dh)), dtype)
    v = jnp.asarray(rng.standard_normal((BH, S, dh)), dtype)
    got = flash_attention(q, k, v, causal=causal, q_block=64, kv_block=32)
    want = flash_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), causal
    )
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), atol=tol * 10, rtol=tol
    )


def test_flash_attention_gqa_matches_model_attention():
    """Kernel (GQA wrapper) == the model's blockwise attention module."""
    from repro.kernels.flash_attention.ops import flash_attention_gqa
    from repro.models.layers import _block_attn

    B, S, N, Kh, dh = 2, 128, 4, 2, 64
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((B, S, N, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Kh, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Kh, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    got = flash_attention_gqa(q, k, v, causal=True, q_block=64, kv_block=64)
    want = _block_attn(q, k, v, pos, pos, True, None, 64, 64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-4)


@pytest.mark.parametrize("B,S,H,hs,chunk", [(2, 64, 2, 32, 16), (1, 128, 4, 64, 16),
                                            (3, 48, 1, 16, 8)])
def test_rwkv6_chunk(B, S, H, hs, chunk):
    from repro.kernels.rwkv6_chunk.ops import rwkv6_chunk, rwkv6_chunk_ref

    rng = np.random.default_rng(B * S + hs)
    r = jnp.asarray(rng.standard_normal((B, S, H, hs)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, hs)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, hs)), jnp.float32)
    logw = -jnp.asarray(rng.uniform(0.01, 2.0, (B, S, H, hs)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, hs)), jnp.float32)
    got = rwkv6_chunk(r, k, v, logw, u, chunk=chunk)
    want = rwkv6_chunk_ref(r, k, v, logw, u, chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-3)


def test_rwkv_model_uses_kernel_path():
    """cfg.use_pallas routes time_mix through the kernel; outputs match."""
    from repro import configs
    from repro.models import Model

    cfg = configs.get_smoke("rwkv6_1_6b").replace(remat=False)
    model_ref = Model(cfg)
    model_k = Model(cfg.replace(use_pallas=True))
    params = model_ref.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)}
    l_ref, _ = model_ref.loss(params, batch)
    l_k, _ = model_k.loss(params, batch)
    np.testing.assert_allclose(float(l_ref), float(l_k), rtol=1e-4)
