"""Incremental view maintenance: delta-driven factor updates and
path-restricted message refresh vs the full-recompute oracle (fresh
compile_ensemble + materialize_join over the effective live tables);
dynamic table/edge mechanics; SumProd message-cache refactor; service
cache invalidation across delta updates and hot swaps; stacked
multi-model scoring; bf16 factor mode."""
import asyncio

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    Arithmetic, BoostConfig, Booster, Channels, QueryCounter, SumProd,
    materialize_join, predict_rows,
)
from repro.incremental import DynamicTable, MaintainedScorer, TableDelta
from repro.relational.generators import (
    chain_schema, delta_stream, snowflake_schema, star_schema,
)
from repro.serving import (
    ModelRegistry, RelationalScoringService, compile_ensemble, score_grouped,
    stack_ensembles,
)


def _fit(sch, n_trees=2, depth=2):
    b = Booster(sch, BoostConfig(n_trees=n_trees, depth=depth,
                                 mode="sketch", ssr_mode="off"))
    return b.fit()[0]


@pytest.fixture(scope="module")
def star_trees(star):
    return _fit(star[0], n_trees=3)


def _small(fixture):
    if fixture == "star":
        return star_schema(seed=11, n_fact=120, n_dim=12)
    if fixture == "chain":
        return chain_schema(seed=12, n_rows=60, n_tables=3, fanout=2)
    return snowflake_schema(seed=13, n_fact=80, n_dim=8, n_sub=4)


# ------------------------------------------------------------- SumProd refactor

def test_messages_refactor_matches_inline_pass(star):
    """The exposed message pass must reproduce the consumed-inline result
    (grouped and reduced) for a non-trivial semiring."""
    sch, J, X, y = star
    sp = SumProd(sch)
    sem = Channels(3)
    rng = np.random.default_rng(0)
    factors = {
        t.name: jnp.asarray(rng.random((t.n_rows, 3)).astype(np.float32))
        for t in sch.tables
    }
    out = sp(sem, factors, group_by="fact")
    jt = sch.join_tree("fact")
    msgs = sp.messages(sem, factors, jt=jt)
    out2 = sp.node_factor(sem, factors, jt, jt.root, msgs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_refresh_messages_matches_full_pass(star):
    """Dirtying one table and refreshing must equal a full re-pass, while
    re-emitting only that table's root path."""
    sch, J, X, y = star
    c = QueryCounter()
    sp = SumProd(sch, counter=c)
    sem = Arithmetic()
    rng = np.random.default_rng(1)
    factors = {t.name: jnp.asarray(rng.random((t.n_rows,)).astype(np.float32))
               for t in sch.tables}
    jt = sch.join_tree("fact")
    msgs = sp.messages(sem, factors, jt=jt)
    full_edges = c.edges

    factors["dim0"] = factors["dim0"] * 2.0
    e0 = c.edges
    msgs2 = sp.refresh_messages(sem, factors, msgs, {sch.index["dim0"]}, jt)
    assert c.edges - e0 == 1 < full_edges       # star: 1 edge of D
    fresh = sp.messages(sem, factors, jt=jt)
    for a, b in zip(msgs2, fresh):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- dynamic tables

def test_dynamic_table_mechanics():
    from repro.core import Table

    t = Table(name="t", columns={"k": np.arange(4, dtype=np.int64),
                                 "x": np.ones(4, np.float32)},
              feature_columns=("x",))
    dt = DynamicTable(t, slack=0.5)
    assert dt.capacity == 6 and dt.n_live == 4
    dt.apply(TableDelta("t", deletes=np.asarray([1])))
    assert dt.n_live == 3 and not dt.live[1]
    with pytest.raises(IndexError):             # double delete
        dt.apply(TableDelta("t", deletes=np.asarray([1])))
    with pytest.raises(IndexError):             # update of dead slot
        dt.apply(TableDelta("t", updates=(np.asarray([1]), {"x": np.zeros(1)})))
    # insert reuses the freed slot first
    changed, grew = dt.apply(TableDelta("t", inserts={
        "k": np.asarray([9]), "x": np.asarray([5.0], np.float32)}))
    assert not grew and changed.tolist() == [1] and dt.columns["x"][1] == 5.0
    # capacity growth on overflow
    changed, grew = dt.apply(TableDelta("t", inserts={
        "k": np.arange(4, dtype=np.int64), "x": np.zeros(4, np.float32)}))
    assert grew and dt.capacity > 6 and dt.n_live == 8
    with pytest.raises(KeyError):               # insert missing a column
        dt.apply(TableDelta("t", inserts={"x": np.zeros(1, np.float32)}))
    eff = dt.effective()
    assert eff.n_rows == 8 and eff.feature_columns == ("x",)


def test_maintained_rejects_key_column_update(star):
    sch, _, _, _ = star
    ms = MaintainedScorer(compile_ensemble(sch, _fit(sch)))
    with pytest.raises(ValueError):
        ms.apply([TableDelta("fact",
                             updates=(np.asarray([0]), {"k0": np.asarray([3])}))])


# --------------------------------------------------- maintained correctness --

def _assert_matches_oracle(ms, group):
    """Maintained grouped scores == fresh full recompute on live tables,
    exactly (f32 path), plus a materialized-join cross-check."""
    tot_o, cnt_o = ms.recompute_oracle(group)
    tot_m, cnt_m = ms.grouped_cached(group)
    live = ms.live_rows(group)
    # capacity-shaped, bit-for-bit: live slots match the fresh recompute,
    # dead slots read (0, 0) on both sides
    np.testing.assert_array_equal(np.asarray(cnt_m), np.asarray(cnt_o))
    np.testing.assert_array_equal(np.asarray(tot_m), np.asarray(tot_o))
    # independent ground truth: brute-force over the materialized join
    eff = ms.effective_schema()
    J = materialize_join(eff)
    X = jnp.stack([J[c] for (_, c) in eff.features], axis=1)
    rows = np.asarray(J["__rows__" + group])
    preds = np.asarray(predict_rows(ms.trees, X))
    n = eff.table(group).n_rows
    np.testing.assert_allclose(np.asarray(tot_o)[live],
                               np.bincount(rows, weights=preds, minlength=n),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(cnt_o)[live],
                               np.bincount(rows, minlength=n), rtol=1e-5)


@pytest.mark.parametrize("shape", ["star", "chain", "snowflake"])
def test_random_delta_stream_matches_recompute_oracle(shape):
    sch = _small(shape)
    trees = _fit(sch)
    c = QueryCounter()
    ms = MaintainedScorer(compile_ensemble(sch, trees), counter=c)
    group = sch.label_table
    ms.grouped_cached(group)                      # prime the message cache
    full_edges = len(sch.join_tree(group).edges)
    inc_edges = []
    for batch in delta_stream(sch, ms.live_rows, seed=17, n_batches=5,
                              ops_per_batch=6):
        e0 = c.edges
        v0 = ms.data_version
        assert ms.apply(batch) == v0 + 1
        _assert_matches_oracle(ms, group)
        inc_edges.append(c.edges - e0)
    # a refresh never exceeds one emission per edge (full-pass cost is
    # the worst case even when a batch touches every table)
    assert len(inc_edges) == 5
    assert all(e <= full_edges for e in inc_edges)


def test_single_table_delta_is_path_local(snowflake):
    """Sub-dimension delta re-emits exactly its 2-edge root path of the
    2·D-edge snowflake tree, and stays oracle-exact."""
    sch, J, X, y = snowflake
    c = QueryCounter()
    ms = MaintainedScorer(compile_ensemble(sch, _fit(sch)), counter=c)
    ms.grouped_cached("fact")
    full_edges = len(sch.join_tree("fact").edges)
    assert full_edges == 4                        # 2 dims × 2 hops
    rng = np.random.default_rng(3)
    slots = ms.live_rows("sub0")[:2]
    e0 = c.edges
    ms.apply([TableDelta("sub0", updates=(slots, {
        "s0f0": rng.standard_normal(2).astype(np.float32)}))])
    ms.grouped_cached("fact")
    assert c.edges - e0 == 2                      # sub0 → dim0 → fact only
    _assert_matches_oracle(ms, "fact")


def test_maintained_grouping_by_every_table(star):
    """Maintenance must stay correct for any grouping root, not just the
    label table (each root has its own message cache + dirty set)."""
    sch, J, X, y = star
    ms = MaintainedScorer(compile_ensemble(sch, _fit(sch)))
    for t in sch.tables:
        ms.grouped_cached(t.name)
    rng = np.random.default_rng(5)
    for batch in delta_stream(sch, ms.live_rows, seed=23, n_batches=3,
                              ops_per_batch=5):
        ms.apply(batch)
        for t in sch.tables:
            _assert_matches_oracle(ms, t.name)


def test_insert_with_new_join_key_then_match(star):
    """A row with a previously unseen key joins nothing until the other
    side inserts the matching key — append-only key dictionaries."""
    sch, J, X, y = star
    ms = MaintainedScorer(compile_ensemble(sch, _fit(sch)))
    group = "fact"
    ms.grouped_cached(group)
    fact = sch.table("fact")
    new_key = int(max(np.asarray(sch.table("dim0").col("k0")).max(),
                      np.asarray(fact.col("k0")).max())) + 5
    row = {c: (np.asarray([new_key], fact.col(c).dtype) if c == "k0"
               else np.zeros(1, fact.col(c).dtype))
           for c in fact.columns}
    changed_before = ms.tables["fact"].n_live
    ms.apply([TableDelta("fact", inserts=row)])
    slot = int(np.setdiff1d(ms.live_rows("fact"),
                            np.arange(changed_before))[0])
    tot, cnt = ms.grouped_cached(group)
    assert float(cnt[slot]) == 0.0               # dangling key: not in join
    _assert_matches_oracle(ms, group)
    # now insert the matching dimension row on the other side
    dim = sch.table("dim0")
    drow = {c: (np.asarray([new_key], dim.col(c).dtype) if c == "k0"
                else np.zeros(1, dim.col(c).dtype)) for c in dim.columns}
    ms.apply([TableDelta("dim0", inserts=drow)])
    tot, cnt = ms.grouped_cached(group)
    assert float(cnt[slot]) > 0.0                # the join now matches
    _assert_matches_oracle(ms, group)


def test_capacity_growth_preserves_scores(star):
    """Inserting past capacity grows the padded store; scores stay exact
    and pre-existing slots keep their ids."""
    sch, J, X, y = star
    ms = MaintainedScorer(compile_ensemble(sch, _fit(sch)), slack=0.05)
    group = "fact"
    live0 = ms.live_rows(group)
    tot0, cnt0 = map(np.asarray, ms.grouped_cached(group))
    fact = sch.table("fact")
    k = ms.tables["fact"].capacity - ms.tables["fact"].n_live + 3
    rng = np.random.default_rng(9)
    ins = {}
    for c in fact.columns:
        v = fact.col(c)
        ins[c] = (rng.integers(0, 12, k).astype(v.dtype) if c.startswith("k")
                  else rng.standard_normal(k).astype(v.dtype))
    cap0 = ms.tables["fact"].capacity
    ms.apply([TableDelta("fact", inserts=ins)])
    assert ms.tables["fact"].capacity > cap0
    tot1, cnt1 = map(np.asarray, ms.grouped_cached(group))
    # pre-existing rows keep their slots AND their scores
    np.testing.assert_array_equal(tot1[live0], tot0[live0])
    np.testing.assert_array_equal(cnt1[live0], cnt0[live0])
    _assert_matches_oracle(ms, group)


def test_jitted_refresh_compile_cache_and_edge_accounting(star):
    """Satellite regression: the path-restricted refresh runs as a jitted
    program cached per (root, dirty-set signature, shape fingerprint).
    ``QueryCounter.edges`` accounting must be UNCHANGED vs the eager
    route — one emission per edge on the dirty tables' root paths on
    every refresh, compile-cache hits included — and the refreshed
    messages must equal an eager full message pass."""
    sch, J, X, y = star
    c = QueryCounter()
    ms = MaintainedScorer(compile_ensemble(sch, _fit(sch)), counter=c)
    ms.grouped_cached("fact")
    rng = np.random.default_rng(0)

    def delta():
        slots = ms.live_rows("dim0")[:3]
        return [TableDelta("dim0", updates=(slots, {
            col: rng.standard_normal(3).astype(np.float32)
            for col in sch.table("dim0").feature_columns}))]

    for _ in range(3):
        ms.apply(delta())
        e0 = c.edges
        ms.grouped_cached("fact")
        assert c.edges - e0 == 1            # star: dim0 root path = 1 edge
        assert len(ms._refresh_fns) == 1    # one compiled program, reused
    # refreshed messages ≡ eager full pass over the same factors
    jt = ms.state.jt("fact")
    fresh = ms._sp.messages(ms._sem, ms.factors, jt=jt)
    for a, b in zip(ms._msgs["fact"], fresh):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    _assert_matches_oracle(ms, "fact")
    # a different dirty set compiles (and caches) its own program
    slots = ms.live_rows("fact")[:2]
    ms.apply([TableDelta("fact", updates=(slots, {
        "x0": rng.standard_normal(2).astype(np.float32)}))])
    e0 = c.edges
    ms.grouped_cached("fact")
    assert c.edges - e0 == 0                # root-only delta: no edge re-emits
    assert len(ms._refresh_fns) == 2
    _assert_matches_oracle(ms, "fact")


# ----------------------------------------------------------------- service --

def test_service_never_serves_stale_scores_across_deltas(star):
    """Satellite regression: the LRU result cache is namespaced by
    (registry version, data_version) — a delta update AND a hot swap must
    both invalidate prior cached entries."""
    sch, J, X, y = star
    trees = _fit(sch)
    ms = MaintainedScorer(compile_ensemble(sch, trees))
    reg = ModelRegistry()
    reg.publish(ms)
    svc = RelationalScoringService(reg, "fact", max_batch=16,
                                   max_wait_ms=2.0, cache_size=256)
    rid = 3

    async def run():
        await svc.start()
        before = await svc.score(rid)
        again = await svc.score(rid)              # cache hit
        assert again == before and svc.stats.cache_hits >= 1

        # delta 1: rewrite the dim0 features this fact row joins — the
        # re-queried score must equal the CURRENT maintained value, not
        # whatever the cache stored pre-delta
        dk = int(ms.tables["fact"].columns["k0"][rid])
        cols = {c: np.asarray([7.5], np.float32)
                for c in sch.table("dim0").feature_columns}
        ms.apply([TableDelta("dim0",
                             updates=(np.asarray([dk]), cols))])
        after = await svc.score(rid)
        tot, cnt = ms.grouped_cached("fact")
        want = float(tot[rid]) / max(float(cnt[rid]), 1.0)
        np.testing.assert_allclose(after, want, rtol=1e-6)

        # delta 2: delete the joined dim row — the fact row leaves the
        # join entirely, so its mean is exactly 0.0 (guaranteed change)
        assert before != 0.0
        ms.apply([TableDelta("dim0", deletes=np.asarray([dk]))])
        after_del = await svc.score(rid)
        assert after_del == 0.0

        # hot swap invalidates too (pre-existing behaviour, re-pinned)
        reg.publish(compile_ensemble(sch, trees[:1]))
        swapped = await svc.score(rid)
        e1 = compile_ensemble(sch, trees[:1])
        t1, c1 = e1.score_grouped("fact")
        np.testing.assert_allclose(
            swapped, float(t1[rid]) / max(float(c1[rid]), 1.0), rtol=1e-6)
        await svc.stop()

    asyncio.run(run())


# ------------------------------------------------------------- multi-model --

def test_stacked_multi_model_single_pass(star, request):
    sch, J, X, y = star
    trees = request.getfixturevalue("star_trees")
    e1 = compile_ensemble(sch, trees[:1])
    e2 = compile_ensemble(sch, trees)
    c = QueryCounter()
    stacked = stack_ensembles([e1, e2], counter=c)
    outs = stacked.score_grouped("fact")
    assert c.count == 1 and len(outs) == 2
    for ens, (tot, cnt) in zip([e1, e2], outs):
        tot_w, cnt_w = score_grouped(ens, "fact")
        np.testing.assert_allclose(np.asarray(tot), np.asarray(tot_w),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_w))


def test_registry_stacked_cache_tracks_versions(star, request):
    sch, J, X, y = star
    trees = request.getfixturevalue("star_trees")
    reg = ModelRegistry()
    reg.publish(compile_ensemble(sch, trees[:1]))
    reg.publish(compile_ensemble(sch, trees[:2]))
    s1 = reg.stacked()
    assert s1 is reg.stacked()                    # cached
    reg.publish(compile_ensemble(sch, trees))
    s2 = reg.stacked()
    assert s2 is not s1 and s2.n_models == 3
    # a published MaintainedScorer can't ride the static join tree its
    # capacity-padded factors don't fit — stacking must reject it loudly
    # rather than crash (or serve garbage) at score time
    ms = MaintainedScorer(compile_ensemble(sch, trees[:1]))
    reg2 = ModelRegistry()
    reg2.publish(ms)
    with pytest.raises(ValueError, match="maintained"):
        reg2.stacked()
    # ...but a static snapshot of its live state stacks fine
    snap = compile_ensemble(ms.effective_schema(), ms.trees)
    outs = stack_ensembles([snap, snap]).score_grouped("fact")
    np.testing.assert_array_equal(np.asarray(outs[0][1]),
                                  np.asarray(outs[1][1]))


# ------------------------------------------------------------------- bf16 --

def test_bf16_factor_mode_close_to_f32_oracle(star, request):
    sch, J, X, y = star
    trees = request.getfixturevalue("star_trees")
    f32 = compile_ensemble(sch, trees)
    bf16 = compile_ensemble(sch, trees, factor_dtype=jnp.bfloat16)
    assert bf16.factors["fact"].dtype == jnp.bfloat16
    tot, cnt = score_grouped(f32, "fact")
    tot_b, cnt_b = score_grouped(bf16, "fact")
    assert tot_b.dtype == jnp.float32             # served outputs stay f32
    # masks are 0/1 and group sizes ≪ 2^8, so bf16 counts stay near-exact
    np.testing.assert_allclose(np.asarray(cnt_b), np.asarray(cnt),
                               rtol=1e-2, atol=0.5)
    np.testing.assert_allclose(np.asarray(tot_b), np.asarray(tot),
                               rtol=2e-2, atol=2e-2)
