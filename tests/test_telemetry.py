"""Live telemetry: flight-recorder ring + trigger dumps, Prometheus/JSON
exposition endpoints, SLO burn-rate monitoring, and the service wiring
that feeds them — per-service metric isolation, partial-failure batch
semantics, staleness lifecycle, and the latency-spike → degraded-state →
flight-dump acceptance path."""
from __future__ import annotations

import asyncio
import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import BoostConfig, Booster
from repro.incremental import MaintainedScorer
from repro.obs import (
    FlightRecorder, MetricsRegistry, PeriodicSampler, SLOMonitor,
    SLOObjective, TelemetryServer, disable_tracing, enable_tracing,
    get_tracer, parse_slo_spec, render_json, render_prometheus, span,
)
from repro.obs.trace import Tracer
from repro.relational.generators import delta_stream
from repro.serving import (
    ModelRegistry, RelationalScoringService, ServiceOverloadedError,
    compile_ensemble,
)
from repro.serving.service import LRUCache


@pytest.fixture(autouse=True)
def _tracing_off():
    """Every test starts and ends with tracing disabled (process-global)."""
    disable_tracing()
    yield
    disable_tracing()
    get_tracer().set_unbounded()


def _fit(sch, n_trees=2, depth=2):
    b = Booster(sch, BoostConfig(n_trees=n_trees, depth=depth,
                                 mode="sketch", ssr_mode="off"))
    trees, _ = b.fit()
    return trees


@pytest.fixture(scope="module")
def star_compiled(star):
    sch = star[0]
    trees = _fit(sch)
    return sch, trees, compile_ensemble(sch, trees)


class FakeClock:
    """Deterministic monotonic clock for SLO window tests."""

    def __init__(self, t=1000.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, s):
        self.t += s


def _mon(objectives, clk, fast=60.0, slow=600.0, **kw):
    kw.setdefault("registry", MetricsRegistry())
    kw.setdefault("state_ttl_s", 0.0)
    return SLOMonitor(objectives, fast_window_s=fast, slow_window_s=slow,
                      clock=clk, **kw)


# -------------------------------------------------------------- exposition --

def test_render_prometheus_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.counter("tel.hits").inc(3)
    reg.gauge("tel.depth").set(2.5)
    h = reg.histogram("tel.lat_ms")
    for v in (1.0, 2.0, 4.0, 8.0):
        h.observe(v)
    text = render_prometheus(reg.snapshot())
    assert "# TYPE repro_tel_hits counter" in text
    assert "repro_tel_hits 3" in text
    assert "# TYPE repro_tel_depth gauge" in text
    assert "repro_tel_depth 2.5" in text
    assert "# TYPE repro_tel_lat_ms summary" in text
    assert 'repro_tel_lat_ms{quantile="0.5"}' in text
    assert 'repro_tel_lat_ms{quantile="0.99"}' in text
    assert "repro_tel_lat_ms_sum 15.0" in text
    assert "repro_tel_lat_ms_count 4" in text
    assert text.endswith("\n")


def test_render_prometheus_sanitizes_names_and_namespace():
    snap = {"weird-name.ms/x": {"type": "counter", "value": 2}}
    text = render_prometheus(snap)
    assert "repro_weird_name_ms_x 2" in text
    text2 = render_prometheus(snap, namespace="")
    assert "\nweird_name_ms_x 2" in "\n" + text2


def test_render_json_roundtrip():
    reg = MetricsRegistry()
    reg.counter("a.b").inc(7)
    doc = json.loads(render_json(reg.snapshot()))
    assert doc["a.b"]["value"] == 7


# --------------------------------------------------------------- SLO spec --

def test_parse_slo_spec_full_grammar():
    objs = {o.name: o for o in
            parse_slo_spec("latency=50ms@0.99, errors=0.01, staleness=5s")}
    assert objs["latency"].kind == "latency"
    assert objs["latency"].target == 50.0
    assert objs["latency"].objective == 0.99
    assert objs["errors"].kind == "error_rate"
    assert objs["errors"].target == 0.01
    assert objs["staleness"].kind == "staleness"
    assert objs["staleness"].target == 5.0


def test_parse_slo_spec_units_and_defaults():
    (lat,) = parse_slo_spec("latency=1s")
    assert lat.target == 1000.0 and lat.objective == 0.99
    (st,) = parse_slo_spec("staleness=500ms")
    assert st.target == 0.5


@pytest.mark.parametrize("bad", ["", "latency", "latency=abc",
                                 "qps=100", "errors=0.01ms"])
def test_parse_slo_spec_rejects(bad):
    with pytest.raises(ValueError):
        parse_slo_spec(bad)


def test_slo_objective_validation():
    with pytest.raises(ValueError):
        SLOObjective("x", "throughput", 1.0)
    with pytest.raises(ValueError):
        SLOObjective("x", "latency", 0.0)
    with pytest.raises(ValueError):
        SLOObjective("x", "latency", 50.0, objective=1.0)
    with pytest.raises(ValueError):
        SLOMonitor([SLOObjective("x", "latency", 50.0)],
                   fast_window_s=60.0, slow_window_s=60.0)


# ---------------------------------------------------------- SLO burn rates --

def test_latency_burn_state_transitions_and_recovery():
    clk = FakeClock()
    mon = _mon([SLOObjective("latency", "latency", 50.0, objective=0.9)], clk)
    for _ in range(100):
        mon.record_latency(10.0)
    assert mon.state() == "healthy"
    # 30 bad / 130 total = 0.23 bad fraction; budget 0.1 → burn 2.3
    for _ in range(30):
        mon.record_latency(500.0)
    assert mon.state() == "degraded"
    rep = mon.evaluate()
    assert rep["objectives"]["latency"]["burn_fast"] == pytest.approx(2.3, rel=0.05)
    # 230/330 bad → burn ≈ 7 → unhealthy
    for _ in range(200):
        mon.record_latency(500.0)
    assert mon.state() == "unhealthy"
    # everything ages out of the slow window → budget no longer burning
    clk.advance(700.0)
    assert mon.state() == "healthy"


def test_fast_spike_alone_does_not_degrade():
    """Multi-window rule: the slow window vetoes a short blip."""
    clk = FakeClock()
    mon = _mon([SLOObjective("latency", "latency", 50.0, objective=0.9)], clk)
    for _ in range(1000):
        mon.record_latency(1.0)
    clk.advance(100.0)                   # good traffic leaves the fast window
    for _ in range(20):
        mon.record_latency(500.0)
    rep = mon.evaluate()
    o = rep["objectives"]["latency"]
    assert o["burn_fast"] >= 6.0         # fast window is all bad
    assert o["burn_slow"] < 1.0          # slow window keeps perspective
    assert rep["state"] == "healthy"


def test_error_rate_objective():
    clk = FakeClock()
    mon = _mon([SLOObjective("errors", "error_rate", 0.05)], clk)
    for _ in range(100):
        mon.record_request(error=False)
    assert mon.state() == "healthy"
    for _ in range(50):
        mon.record_request(error=True)
    assert mon.state() == "unhealthy"    # 33% errors vs 5% budget → burn 6.7
    assert mon.compliance("errors") == pytest.approx(100 / 150)


def test_staleness_objective_is_gauge_semantics():
    clk = FakeClock()
    mon = _mon([SLOObjective("staleness", "staleness", 5.0)], clk)
    mon.set_staleness(2.0)
    assert mon.state() == "healthy"
    mon.set_staleness(12.0)
    assert mon.state() == "degraded"
    mon.set_staleness(40.0)
    assert mon.state() == "unhealthy"
    mon.set_staleness(0.0)
    assert mon.state() == "healthy"


def test_no_traffic_burns_no_budget():
    clk = FakeClock()
    mon = _mon([SLOObjective("latency", "latency", 50.0)], clk)
    assert mon.state() == "healthy"
    assert mon.compliance("latency") is None


def test_evaluate_mirrors_gauges_into_registry():
    clk = FakeClock()
    reg = MetricsRegistry()
    mon = _mon([SLOObjective("latency", "latency", 50.0, objective=0.9)],
               clk, registry=reg)
    for _ in range(10):
        mon.record_latency(500.0)
    mon.evaluate()
    snap = reg.snapshot()
    assert snap["slo.latency.burn_fast"]["value"] >= 6.0
    assert snap["slo.state"]["value"] == 2    # unhealthy


def test_state_ttl_caches_evaluation():
    clk = FakeClock()
    mon = _mon([SLOObjective("latency", "latency", 50.0, objective=0.9)],
               clk, state_ttl_s=10.0)
    assert mon.state() == "healthy"
    for _ in range(50):
        mon.record_latency(500.0)
    assert mon.state() == "healthy"      # cached verdict inside the TTL
    clk.advance(11.0)
    assert mon.state() != "healthy"


# ------------------------------------------------------------ flight recorder --

def _feed(tr, n, start=0):
    for i in range(start, start + n):
        tr.record({"name": f"s{i}", "ts_ms": float(i), "dur_ms": 1.0,
                   "tid": 0, "depth": 0})


def test_flight_ring_wraps_and_keeps_newest(tmp_path):
    tr = Tracer(jax_annotations=False)
    fl = FlightRecorder(capacity=16, out_dir=str(tmp_path), tracer=tr).start()
    assert tr.enabled and tr.ring_capacity == 16
    _feed(tr, 40)
    assert len(tr.events) == 16          # O(1) memory: oldest overwritten
    names = [e["name"] for e in fl.snapshot()]
    assert names == [f"s{i}" for i in range(24, 40)]
    fl.stop()
    assert tr.ring_capacity is None and not tr.enabled


def test_flight_trigger_dump_is_perfetto_loadable(tmp_path):
    tr = Tracer(jax_annotations=False)
    fl = FlightRecorder(capacity=8, out_dir=str(tmp_path), name="t",
                        tracer=tr).start()
    _feed(tr, 5)
    path = fl.trigger("manual test", batch=3)
    assert path and path.endswith("FLIGHT_t_000.json")
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    assert len(events) == 6              # 5 spans + the trigger instant
    assert all(e["ph"] in ("X", "i") for e in events)
    trig = events[-1]
    assert trig["name"] == "flight.trigger" and trig["ph"] == "i"
    assert trig["s"] == "g" and trig["args"]["reason"] == "manual test"
    assert trig["args"]["batch"] == 3


def test_flight_latency_and_error_triggers(tmp_path):
    tr = Tracer(jax_annotations=False)
    fl = FlightRecorder(capacity=8, out_dir=str(tmp_path), tracer=tr,
                        latency_trigger_ms=100.0, cooldown_s=0.0).start()
    assert fl.observe_latency(50.0) is None          # under threshold
    assert fl.observe_latency(150.0) is not None
    assert fl.observe_error(RuntimeError("boom")) is not None
    fl2 = FlightRecorder(capacity=8, out_dir=str(tmp_path), name="noerr",
                         tracer=tr, error_trigger=False)
    assert fl2.observe_error(RuntimeError("boom")) is None


def test_flight_cooldown_and_budget_suppress(tmp_path):
    tr = Tracer(jax_annotations=False)
    fl = FlightRecorder(capacity=8, out_dir=str(tmp_path), name="cd",
                        tracer=tr, cooldown_s=1000.0).start()
    assert fl.trigger("first") is not None
    assert fl.trigger("second") is None              # inside the cooldown
    assert fl.suppressed == 1
    fl3 = FlightRecorder(capacity=8, out_dir=str(tmp_path), name="cap",
                         tracer=tr, cooldown_s=0.0, max_dumps=2).start()
    assert sum(fl3.trigger(f"n{i}") is not None for i in range(5)) == 2
    assert fl3.suppressed == 3
    assert fl3.status()["suppressed"] == 3


def test_flight_trigger_thread_safety(tmp_path):
    tr = Tracer(jax_annotations=False)
    fl = FlightRecorder(capacity=64, out_dir=str(tmp_path), name="thr",
                        tracer=tr, latency_trigger_ms=1.0, cooldown_s=0.0,
                        max_dumps=4).start()

    def hammer(k):
        for i in range(10):
            _feed(tr, 1, start=k * 100 + i)
            fl.observe_latency(5.0, worker=k)

    threads = [threading.Thread(target=hammer, args=(k,)) for k in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dumps = [d for d in fl.status()["dumps"] if d["path"]]
    assert len(dumps) == 4               # budget enforced under contention
    assert fl.suppressed == 80 - 4
    for d in dumps:                      # every dump is a complete document
        with open(d["path"]) as f:
            assert json.load(f)["traceEvents"]


def test_tracer_clear_resets_thread_local_stacks():
    """Regression: a span leaked on ANY thread must not skew the depth of
    later spans after clear() — clear resets every thread's stack."""
    enable_tracing(jax_annotations=False)
    leaked = span("leaked")
    leaked.__enter__()                   # never exited: simulates a leak
    ready, resume = threading.Event(), threading.Event()

    def worker():
        w = span("w_leaked")
        w.__enter__()                    # leak on a second thread too
        ready.set()
        resume.wait(5.0)
        with span("w_after"):
            pass

    t = threading.Thread(target=worker)
    t.start()
    ready.wait(5.0)
    get_tracer().clear()
    resume.set()
    t.join(5.0)
    with span("after"):
        pass
    depths = {e["name"]: e["depth"] for e in get_tracer().events}
    assert depths["after"] == 0
    assert depths["w_after"] == 0


# ------------------------------------------------------------ HTTP endpoints --

def _get(url):
    with urllib.request.urlopen(url, timeout=5.0) as r:
        return r.status, r.headers.get("Content-Type", ""), r.read().decode()


def test_telemetry_server_endpoints(tmp_path):
    reg = MetricsRegistry()
    reg.counter("tel.hits").inc(3)
    clk = FakeClock()
    slo = _mon([SLOObjective("latency", "latency", 50.0, objective=0.9)],
               clk, registry=reg)
    tr = Tracer(jax_annotations=False)
    tr.set_ring(8)
    _feed(tr, 5)
    flight = FlightRecorder(capacity=8, out_dir=str(tmp_path), tracer=tr)
    ts = TelemetryServer(registries=[reg], slo=slo, flight=flight, tracer=tr,
                         status_fn=lambda: {"model_version": 7})
    port = ts.start_in_thread()
    assert port > 0 and ts.url("/healthz").endswith(f":{port}/healthz")
    try:
        code, ctype, body = _get(ts.url("/metricsz"))
        assert code == 200 and ctype.startswith("text/plain")
        assert "repro_tel_hits 3" in body

        code, ctype, body = _get(ts.url("/metricsz?format=json"))
        assert code == 200 and ctype == "application/json"
        assert json.loads(body)["tel.hits"]["value"] == 3

        code, _, body = _get(ts.url("/healthz"))
        doc = json.loads(body)
        assert code == 200 and doc["state"] == "healthy"

        for _ in range(50):              # drive the SLO past both windows
            slo.record_latency(500.0)
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(ts.url("/healthz"))
        assert ei.value.code == 503
        assert json.loads(ei.value.read().decode())["state"] == "unhealthy"

        code, _, body = _get(ts.url("/statusz"))
        doc = json.loads(body)
        assert code == 200 and doc["model_version"] == 7
        assert doc["uptime_s"] >= 0.0
        assert doc["slo"]["state"] == "unhealthy"
        assert doc["flight"]["capacity"] == 8

        code, _, body = _get(ts.url("/tracez?n=2"))
        doc = json.loads(body)
        assert code == 200 and doc["ring_capacity"] == 8
        assert [s["name"] for s in doc["spans"]] == ["s3", "s4"]

        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(ts.url("/nope"))
        assert ei.value.code == 404
    finally:
        ts.stop_thread()


def test_telemetry_server_without_slo_reports_healthy():
    reg = MetricsRegistry()
    ts = TelemetryServer(registries=[reg])
    ts.start_in_thread()
    try:
        code, _, body = _get(ts.url("/healthz"))
        assert code == 200 and json.loads(body)["slo"] is None
    finally:
        ts.stop_thread()


def test_telemetry_server_status_fn_error_is_contained():
    def boom():
        raise RuntimeError("status exploded")

    ts = TelemetryServer(registries=[MetricsRegistry()], status_fn=boom)
    ts.start_in_thread()
    try:
        code, _, body = _get(ts.url("/statusz"))
        assert code == 200
        assert "status exploded" in json.loads(body)["status_error"]
    finally:
        ts.stop_thread()


def test_periodic_sampler_appends_jsonl_deltas(tmp_path):
    reg = MetricsRegistry()
    path = tmp_path / "telemetry_test.jsonl"
    s = PeriodicSampler(str(path), interval_s=60.0, registries=[reg],
                        extra_fn=lambda: {"ctx": 42})
    s.start()
    reg.counter("work.items").inc(5)
    line = s.sample()
    assert line["series"]["work.items"]["value"] == 5   # per-window delta
    reg.counter("work.items").inc(2)
    s.stop()                              # writes the final window
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) == s.samples == 2
    assert all(set(l) >= {"t", "dt_s", "series", "ctx"} for l in lines)
    assert lines[-1]["series"]["work.items"]["value"] == 2
    assert lines[-1]["ctx"] == 42


# ----------------------------------------------------------- service wiring --

def test_lru_cache_isolated_per_registry():
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    c1, c2 = LRUCache(4, registry=r1), LRUCache(4, registry=r2)
    c1.put("k", 1.0)
    c1.get("k")
    c1.get("missing")
    c2.get("missing")
    s1, s2 = r1.snapshot(), r2.snapshot()
    assert s1["service.lru.hits"]["value"] == 1
    assert s1["service.lru.misses"]["value"] == 1
    assert s2["service.lru.hits"]["value"] == 0
    assert s2["service.lru.misses"]["value"] == 1


def test_cohosted_services_do_not_mix_cache_series(star_compiled):
    """Regression: the LRU used to report into the process-global
    registry, so two services' hit/miss series merged."""
    sch, trees, ens = star_compiled

    async def run():
        reg_a, reg_b = ModelRegistry(), ModelRegistry()
        reg_a.publish(ens)
        reg_b.publish(ens)
        a = RelationalScoringService(reg_a, sch.label_table, max_wait_ms=0.2)
        b = RelationalScoringService(reg_b, sch.label_table, max_wait_ms=0.2)
        await a.start()
        await b.start()
        await a.score_many([0, 1])                  # populate a's cache
        await a.score_many([0, 1, 0, 1])            # 4 hits
        await b.score_many([2])                     # misses only
        await a.stop()
        await b.stop()
        return a, b

    a, b = asyncio.run(run())
    sa = a.stats.registry.snapshot()
    sb = b.stats.registry.snapshot()
    assert sa["service.lru.hits"]["value"] == a.cache.hits == 4
    assert sb["service.lru.hits"]["value"] == b.cache.hits == 0
    assert sb["service.lru.misses"]["value"] == b.cache.misses == 1


def test_score_many_partial_failure_keeps_siblings(star_compiled):
    """Regression: one bad row id used to cancel every co-batched
    sibling via bare gather; now survivors resolve and cache first."""
    sch, trees, ens = star_compiled
    n = sch.table(sch.label_table).n_rows

    async def run():
        reg = ModelRegistry()
        reg.publish(ens)
        svc = RelationalScoringService(reg, sch.label_table, max_wait_ms=0.2)
        await svc.start()
        with pytest.raises(IndexError):
            await svc.score_many([0, n + 50, 1])
        # siblings were scored and cached despite the rejected id
        again = await svc.score_many([0, 1])
        await svc.stop()
        return svc, again

    svc, again = asyncio.run(run())
    assert len(again) == 2 and all(isinstance(v, float) for v in again)
    assert svc.stats.rejected == 1
    assert svc.stats.requests == 4       # the bad id never counted
    assert svc.cache.hits >= 2           # second pass served from cache
    assert svc.stats.errors == 0


def test_dispatch_failure_counts_errors_and_triggers_flight(star_compiled, tmp_path):
    sch, trees, ens = star_compiled
    tr = Tracer(jax_annotations=False)
    flight = FlightRecorder(capacity=8, out_dir=str(tmp_path), name="err",
                            tracer=tr, cooldown_s=0.0).start()

    async def run():
        reg = ModelRegistry()
        reg.publish(ens)
        svc = RelationalScoringService(reg, sch.label_table,
                                       max_wait_ms=0.2, flight=flight)

        def broken(batch):
            raise RuntimeError("scorer exploded")

        svc._dispatch = broken
        await svc.start()
        with pytest.raises(RuntimeError, match="scorer exploded"):
            await svc.score_many([0, 1, 2])
        await svc.stop()
        return svc

    svc = asyncio.run(run())
    assert svc.stats.errors == 3
    dumps = [d for d in flight.status()["dumps"] if d["path"]]
    assert dumps and "RuntimeError" in dumps[0]["reason"]


def test_unhealthy_slo_sheds_admissions(star_compiled):
    sch, trees, ens = star_compiled
    clk = FakeClock()
    slo = _mon([SLOObjective("latency", "latency", 10.0, objective=0.9)], clk)
    for _ in range(50):
        slo.record_latency(500.0)        # burn ≈ 10 on both windows
    assert slo.state() == "unhealthy"

    async def run(svc):
        await svc.start()
        try:
            return await svc.score(0)
        finally:
            await svc.stop()

    reg = ModelRegistry()
    reg.publish(ens)
    svc = RelationalScoringService(reg, sch.label_table, slo=slo)
    with pytest.raises(ServiceOverloadedError):
        asyncio.run(run(svc))
    assert svc.stats.shed == 1 and svc.stats.requests == 0

    svc2 = RelationalScoringService(reg, sch.label_table, slo=slo,
                                    shed_when_unhealthy=False)
    assert isinstance(asyncio.run(run(svc2)), float)
    assert svc2.stats.shed == 0


def test_degraded_slo_collapses_coalescing_window(star_compiled):
    """Overload signal: degraded state must stop holding batches open
    for the full max_wait (here 0.5 s — failure would be visible)."""
    sch, trees, ens = star_compiled

    class Degraded:
        def state(self):
            return "degraded"

        def record_latency(self, ms):
            pass

        def record_request(self, error=False):
            pass

        def set_staleness(self, s):
            pass

    async def run():
        reg = ModelRegistry()
        reg.publish(ens)
        svc = RelationalScoringService(reg, sch.label_table, max_batch=1000,
                                       max_wait_ms=500.0, slo=Degraded(),
                                       cache_size=0)
        await svc.start()
        await svc.score(0)               # absorb the jit warmup
        t0 = time.perf_counter()
        await svc.score_many(list(range(8)))
        dt = time.perf_counter() - t0
        await svc.stop()
        return dt

    assert asyncio.run(run()) < 0.4      # did not wait out the window


def test_latency_spike_degrades_health_and_dumps_flight(star_compiled, tmp_path):
    """Acceptance: an injected latency spike flips the burn-rate state
    off healthy AND triggers a Perfetto-loadable flight dump."""
    sch, trees, ens = star_compiled
    slo = SLOMonitor(parse_slo_spec("latency=20ms@0.9"),
                     fast_window_s=0.5, slow_window_s=2.0,
                     registry=MetricsRegistry(), state_ttl_s=0.0)
    tr = Tracer(jax_annotations=False)
    flight = FlightRecorder(capacity=64, out_dir=str(tmp_path), name="spike",
                            tracer=tr, latency_trigger_ms=60.0,
                            cooldown_s=0.0).start()

    async def run():
        reg = ModelRegistry()
        reg.publish(ens)
        svc = RelationalScoringService(reg, sch.label_table, max_wait_ms=0.2,
                                       cache_size=0, flight=flight,
                                       shed_when_unhealthy=False)
        await svc.start()
        await svc.score_many(list(range(16)))        # jit warmup
        svc.slo = slo
        for _ in range(4):                           # clean traffic
            await svc.score_many(list(range(16)))
        clean = slo.state()
        orig = svc._dispatch
        svc._dispatch = lambda b: (time.sleep(0.08), orig(b))[1]
        for _ in range(3):                           # spiked traffic
            await svc.score_many(list(range(16)))
        spiked = slo.state()
        await svc.stop()
        return clean, spiked

    clean, spiked = asyncio.run(run())
    assert clean == "healthy"
    assert spiked != "healthy"
    dumps = [d for d in flight.status()["dumps"] if d["path"]]
    assert dumps
    with open(dumps[0]["path"]) as f:
        events = json.load(f)["traceEvents"]
    assert any(e["name"] == "flight.trigger" and e["ph"] == "i"
               for e in events)


def test_service_staleness_gauge_tracks_maintained_scorer(star_compiled):
    sch, trees, _ = star_compiled
    ms = MaintainedScorer(compile_ensemble(sch, trees))
    group = sch.label_table
    ms.grouped_cached(group)
    assert ms.staleness_s() == 0.0
    batch = next(iter(delta_stream(sch, ms.live_rows, seed=3,
                                   n_batches=1, ops_per_batch=2)))
    ms.apply(batch)
    time.sleep(0.01)
    stale = ms.staleness_s()
    assert stale > 0.0                   # applied but not yet refreshed

    clk = FakeClock()
    slo = _mon([SLOObjective("staleness", "staleness", 5.0)], clk)

    async def run():
        reg = ModelRegistry()
        reg.publish(ms)
        svc = RelationalScoringService(reg, group, max_wait_ms=0.2, slo=slo)
        await svc.start()
        out = await svc.score(0)         # dispatch refreshes the view
        await svc.stop()
        return svc, out

    svc, out = asyncio.run(run())
    assert isinstance(out, float)
    assert ms.staleness_s() == 0.0       # refresh cleared the lag
    # the gauge sampled the pre-refresh lag the batch resolved
    assert svc.stats.snapshot()["staleness_s"] >= stale


def test_stats_snapshot_consistent_under_concurrent_writers():
    from repro.serving.service import ServiceStats

    stats = ServiceStats()
    stop = threading.Event()
    N, n_workers = 500, 4

    def work():
        for i in range(N):
            stats._requests.inc()
            stats.latency_ms.observe(1.0 + (i % 7))
            stats.queue_wait_ms.observe(0.5)

    threads = [threading.Thread(target=work) for _ in range(n_workers)]
    for t in threads:
        t.start()
    seen = []
    while any(t.is_alive() for t in threads):
        snap = stats.snapshot()          # must never raise mid-update
        assert snap["latency_ms"]["count"] <= snap["requests"] + n_workers
        seen.append(snap["requests"])
    for t in threads:
        t.join()
    stop.set()
    assert seen == sorted(seen)          # counters are monotone
    final = stats.snapshot()
    assert final["requests"] == N * n_workers
    assert final["latency_ms"]["count"] == N * n_workers


def test_flight_ring_survives_concurrent_span_writers(tmp_path):
    tr = Tracer(jax_annotations=False)
    fl = FlightRecorder(capacity=32, out_dir=str(tmp_path), name="conc",
                        tracer=tr).start()

    def work(k):
        for i in range(200):
            tr.record({"name": f"w{k}.{i}", "ts_ms": float(i),
                       "dur_ms": 0.1, "tid": k, "depth": 0})

    threads = [threading.Thread(target=work, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for _ in range(20):
        assert len(fl.snapshot()) <= 32  # bounded at every instant
    for t in threads:
        t.join()
    assert len(fl.snapshot()) == 32
    path = fl.trigger("post-hammer")
    with open(path) as f:
        assert len(json.load(f)["traceEvents"]) == 33
