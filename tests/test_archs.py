"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step with shape + finiteness asserts, plus prefill→decode consistency
(decode logits must match a full forward at the same position)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import Model


def _batch(cfg, key, B=2, S=32):
    ks = jax.random.split(key, 3)
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if cfg.frontend == "patches":
        b["patches"] = jax.random.normal(ks[1], (B, S // 2, cfg.d_model)) * 0.02
        b["tokens"] = b["tokens"][:, : S - S // 2]
    if cfg.is_encdec:
        b["src_frames"] = jax.random.normal(ks[2], (B, S // 2, cfg.d_model)) * 0.02
        b["tokens"] = b["tokens"][:, : S // 2]
    return b


# the heaviest reduced configs on CPU (see --durations); deselected from
# tier-1 by the default `-m "not slow"` addopts, run via `pytest -m ""`
_HEAVY = {"hymba_1_5b", "qwen2_5_32b", "dbrx_132b", "seamless_m4t_medium",
          "rwkv6_1_6b", "llava_next_34b"}
_mark_heavy = lambda archs: [
    pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a for a in archs
]


@pytest.mark.parametrize("arch", _mark_heavy(configs.ARCHS))
def test_train_step_smoke(arch):
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        l, m = model.loss(p, batch)
        return l, m

    (loss, metrics), grads = jax.jit(
        lambda p: jax.value_and_grad(loss_fn, has_aux=True)(p)
    )(params)
    assert np.isfinite(float(loss)), arch
    gn = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads)
    )
    assert np.isfinite(float(gn)) and float(gn) > 0, arch
    # full-config sanity: the exact assignment numbers are importable
    full = configs.get(arch)
    assert full.n_layers >= cfg.n_layers


@pytest.mark.parametrize("arch", _mark_heavy(
    ["tinyllama_1_1b", "dbrx_132b", "rwkv6_1_6b", "hymba_1_5b",
     "seamless_m4t_medium", "llava_next_34b"]))
def test_prefill_decode_consistency(arch):
    """decode_step after prefill(S) must reproduce the forward logits the
    train path computes at position S (same weights, same prefix)."""
    cfg = configs.get_smoke(arch).replace(remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = _batch(cfg, jax.random.PRNGKey(1), B=B, S=S)

    logits_p, cache = jax.jit(model.prefill)(params, batch)
    assert np.all(np.isfinite(np.asarray(logits_p)))

    next_tok = jnp.argmax(logits_p, -1).astype(jnp.int32)
    logits_d, cache2 = jax.jit(model.decode_step)(params, cache, next_tok)
    assert np.all(np.isfinite(np.asarray(logits_d)))

    # oracle: rerun prefill on the extended sequence; its last-position
    # logits must match the decode step's output
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], next_tok[:, None]], 1)
    logits_o, _ = jax.jit(model.prefill)(params, batch2)
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(logits_o), atol=0.08, rtol=0.05
    )


def test_rwkv_chunked_equals_naive():
    """Chunked WKV == step-by-step recurrence."""
    from repro.models.rwkv6 import rwkv_chunked

    B, S, H, hs = 2, 32, 3, 8
    rng = np.random.default_rng(0)
    r = jnp.asarray(rng.standard_normal((B, S, H, hs)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, hs)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, hs)), jnp.float32)
    logw = -jnp.asarray(rng.uniform(0.01, 2.0, (B, S, H, hs)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, hs)), jnp.float32)

    got = rwkv_chunked(r, k, v, logw, u, chunk=8)

    Sst = np.zeros((B, H, hs, hs), np.float32)
    w = np.exp(np.asarray(logw))
    rn, kn, vn, un = map(np.asarray, (r, k, v, u))
    want = np.zeros((B, S, H, hs), np.float32)
    for t in range(S):
        kv = np.einsum("bhk,bhd->bhkd", kn[:, t], vn[:, t])
        want[:, t] = np.einsum("bhk,bhkd->bhd", rn[:, t], Sst + un[None, :, :, None] * kv)
        Sst = w[:, t][..., None] * Sst + kv
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4, rtol=2e-3)


def test_ssm_chunked_equals_naive():
    from repro.models.ssm import ssm_chunked

    B, S, H, P, N = 2, 32, 3, 8, 4
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, H, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, H, N)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 1.0, (B, S, H)), jnp.float32)
    loga = -jnp.asarray(rng.uniform(0.01, 1.5, (B, S, H)), jnp.float32)
    Dsk = jnp.asarray(rng.standard_normal((H, P)), jnp.float32)

    got = ssm_chunked(x, Bm, Cm, dt, loga, Dsk, chunk=8)

    xn, Bn, Cn, dn, an, Dn = map(np.asarray, (x, Bm, Cm, dt, loga, Dsk))
    h = np.zeros((B, H, N, P), np.float32)
    want = np.zeros((B, S, H, P), np.float32)
    for t in range(S):
        h = np.exp(an[:, t])[..., None, None] * h + np.einsum(
            "bhn,bh,bhp->bhnp", Bn[:, t], dn[:, t], xn[:, t]
        )
        want[:, t] = np.einsum("bhn,bhnp->bhp", Cn[:, t], h) + xn[:, t] * Dn
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4, rtol=2e-3)


def test_blockwise_attention_equals_dense():
    """Online-softmax blockwise attention == full softmax reference,
    causal and windowed, GQA grouping."""
    from repro.models.layers import _block_attn

    B, S, N, Kh, dh = 2, 40, 4, 2, 16
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((B, S, N, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Kh, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Kh, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)

    for window in (None, 8):
        got = _block_attn(q, k, v, pos, pos, True, window, 16, 16)
        # dense reference
        G = N // Kh
        qg = q.reshape(B, S, Kh, G, dh) / np.sqrt(dh)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k)
        mask = jnp.tril(jnp.ones((S, S), bool))
        if window is not None:
            mask &= (jnp.arange(S)[:, None] - jnp.arange(S)[None, :]) < window
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, -1)
        want = jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(B, S, N * dh)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_blockwise_attention_grads_equal_dense():
    """Custom flash-style VJP == autodiff through dense softmax."""
    from repro.models.layers import _block_attn

    B, S, N, Kh, dh = 2, 33, 4, 2, 8
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((B, S, N, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Kh, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Kh, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)

    def dense(q, k, v, window):
        G = N // Kh
        qg = q.reshape(B, S, Kh, G, dh) / np.sqrt(dh)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k)
        mask = jnp.tril(jnp.ones((S, S), bool))
        if window is not None:
            mask &= (jnp.arange(S)[:, None] - jnp.arange(S)[None, :]) < window
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, -1)
        return jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(B, S, N * dh)

    for window in (None, 7):
        f_blk = lambda q, k, v: jnp.sum(
            jnp.sin(_block_attn(q, k, v, pos, pos, True, window, 16, 16))
        )
        f_dns = lambda q, k, v: jnp.sum(jnp.sin(dense(q, k, v, window)))
        g_blk = jax.grad(f_blk, argnums=(0, 1, 2))(q, k, v)
        g_dns = jax.grad(f_dns, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g_blk, g_dns):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=3e-4, rtol=1e-3)
