"""Tensor-sketch properties: Thm 1.2 (AMM), linearity, Parseval,
coefficient↔frequency domain equivalence, and the SumProd-embedded
sketch vs the dense oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core import (
    Hash2, PolyCoeff, PolyFreq, SumProd, TableHashes, count_sketch_dense,
    sketch_factors, tensor_sketch_dense, materialize_join,
)
from repro.relational.generators import star_schema


@pytest.mark.parametrize("k", [16, 64, 256])
def test_coeff_freq_equivalence(k):
    pc, pf = PolyCoeff(k), PolyFreq(k)
    a = jax.random.normal(jax.random.PRNGKey(0), (7, k))
    b = jax.random.normal(jax.random.PRNGKey(1), (7, k))
    np.testing.assert_allclose(
        np.asarray(pf.to_coeff(pf.mul(pc.to_freq(a), pc.to_freq(b)))),
        np.asarray(pc.mul(a, b)),
        atol=1e-4,
    )
    # Parseval
    np.testing.assert_allclose(
        np.asarray(pf.norm_sq(pc.to_freq(a))), np.asarray(pc.norm_sq(a)), rtol=1e-4
    )


def test_count_sketch_inner_product_unbiased():
    """⟨Sa, Sb⟩ ≈ ⟨a, b⟩ across hash draws (AMM, Thm 1.2)."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal(512), jnp.float32)
    b = jnp.asarray(rng.standard_normal(512), jnp.float32)
    exact = float(a @ b)
    ests = []
    for t in range(64):
        h = Hash2.make(jax.random.PRNGKey(t), 128)
        ests.append(float(count_sketch_dense(a, h) @ count_sketch_dense(b, h)))
    err = abs(np.mean(ests) - exact)
    assert err < 0.2 * float(jnp.linalg.norm(a) * jnp.linalg.norm(b))


def test_sumprod_sketch_equals_dense_oracle():
    """Sketch computed *inside* the SumProd query == sketching the explicit
    Kronecker-product vector (n_fact=1 so the join is a single Kronecker)."""
    import numpy as np

    rng = np.random.default_rng(0)
    k = 64
    # two tiny tables joined on a single shared key value → J = cross product
    from repro.core import Schema, Table

    na, nb = 5, 7
    ta = Table("A", {"k": np.zeros(na, np.int64), "fa": rng.standard_normal(na).astype(np.float32)})
    tb = Table("B", {"k": np.zeros(nb, np.int64), "fb": rng.standard_normal(nb).astype(np.float32)})
    sch = Schema([ta, tb], label=("A", "fa"))
    sp = SumProd(sch)
    hashes = TableHashes.make(jax.random.PRNGKey(1), sch, k)
    sem = PolyFreq(k)
    f = sketch_factors(sch, sem, hashes, "A", sch.labels)
    got = np.asarray(sem.to_coeff(sp(sem, f)))

    # dense oracle: vector u ⊙ v with u = labels (A side), v = ones (B side)
    # hashed with w_ids as indices
    wa, wb = np.asarray(sch.w_ids["A"]), np.asarray(sch.w_ids["B"])
    da, db = sch.domain_sizes["A"], sch.domain_sizes["B"]
    u = np.zeros(da, np.float32)
    np.add.at(u, wa, np.asarray(sch.labels))
    v = np.zeros(db, np.float32)
    np.add.at(v, wb, 1.0)
    want = np.asarray(
        tensor_sketch_dense(
            [jnp.asarray(u), jnp.asarray(v)],
            [hashes.hashes["A"], hashes.hashes["B"]],
            k,
        )
    )
    np.testing.assert_allclose(got, want, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_norm_concentration(seed):
    """‖Y′‖² concentrates around ‖Y‖² (k = 256 ⇒ ε ≈ 1/√k regime)."""
    sch = star_schema(seed=seed % 17, n_fact=200, n_dim=16)
    sp = SumProd(sch)
    J = materialize_join(sch)
    y = np.asarray(J[sch.label_column])
    sem = PolyFreq(256)
    hashes = TableHashes.make(jax.random.PRNGKey(seed), sch, 256)
    f = sketch_factors(sch, sem, hashes, sch.label_table, sch.labels)
    est = float(sem.norm_sq(sp(sem, f)))
    exact = float((y ** 2).sum())
    assert abs(est - exact) / exact < 0.6  # generous single-draw tail bound


def test_sketch_linearity():
    sch = star_schema(seed=2, n_fact=120, n_dim=12)
    sp = SumProd(sch)
    sem = PolyFreq(64)
    hashes = TableHashes.make(jax.random.PRNGKey(5), sch, 64)
    f = sketch_factors(sch, sem, hashes, sch.label_table, sch.labels)
    total = sp(sem, f)
    grouped = sp(sem, f, group_by="dim0")
    np.testing.assert_allclose(
        np.asarray(grouped.sum(0)), np.asarray(total), rtol=1e-3, atol=1e-3
    )
