"""Serving subsystem: compiled one-pass scorer vs the seed per-leaf loop
AND the materialized-join oracle on star/chain/snowflake schemas; Pallas
kernel routing; interactive entry points; micro-batching service
(coalescing, LRU cache, versioned hot swap); pipeline integration."""
import asyncio

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import BoostConfig, Booster, QueryCounter, predict_rows
from repro.serving import (
    LRUCache, ModelRegistry, RelationalScoringService, compile_ensemble,
    score_fresh, score_grouped, score_grouped_reference, score_mean_rows,
    score_rows,
)


def _fit(sch, n_trees=3, depth=2):
    b = Booster(sch, BoostConfig(n_trees=n_trees, depth=depth,
                                 mode="sketch", ssr_mode="off"))
    trees, _ = b.fit()
    return trees


@pytest.fixture(scope="module")
def star_trees(star):
    """One shared 3-tree fit on the star schema; tests needing fewer
    trees slice it (a sliced list is a valid smaller ensemble)."""
    return _fit(star[0])


def _oracle(sch, J, X, trees, group):
    rows = np.asarray(J["__rows__" + group])
    preds = np.asarray(predict_rows(trees, X))
    n = sch.table(group).n_rows
    return (np.bincount(rows, weights=preds, minlength=n),
            np.bincount(rows, minlength=n))


@pytest.mark.parametrize("fixture", ["star", "chain", "snowflake"])
def test_score_grouped_matches_reference_and_oracle(fixture, request):
    sch, J, X, y = request.getfixturevalue(fixture)
    trees = (request.getfixturevalue("star_trees") if fixture == "star"
             else _fit(sch, n_trees=2))
    group = sch.label_table

    c_old, c_new = QueryCounter(), QueryCounter()
    tot_ref, cnt_ref = score_grouped_reference(sch, trees, group, counter=c_old)
    ens = compile_ensemble(sch, trees, counter=c_new)
    tot, cnt = score_grouped(ens, group)

    want_tot, want_cnt = _oracle(sch, J, X, trees, group)
    np.testing.assert_allclose(np.asarray(tot), want_tot, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(cnt), want_cnt, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(tot), np.asarray(tot_ref),
                               rtol=1e-3, atol=1e-3)
    # one stacked pass replaces the n_trees·L + 1 per-leaf passes
    assert c_new.count == 1
    assert c_old.count == sum(int(t.leaf.shape[0]) for t in trees) + 1
    assert c_old.count / c_new.count >= 5


def test_score_grouped_every_table(star, star_trees):
    """Grouping by dimension tables must match the oracle too."""
    sch, J, X, y = star
    trees = star_trees
    ens = compile_ensemble(sch, trees)
    for t in sch.tables:
        tot, cnt = score_grouped(ens, t.name)
        want_tot, want_cnt = _oracle(sch, J, X, trees, t.name)
        np.testing.assert_allclose(np.asarray(tot), want_tot, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(cnt), want_cnt, rtol=1e-5)


def test_kernel_routed_scoring_matches(star, star_trees):
    sch, J, X, y = star
    trees = star_trees[:2]
    tot, cnt = score_grouped(compile_ensemble(sch, trees), "fact")
    tot_k, cnt_k = score_grouped(compile_ensemble(sch, trees, use_kernel=True), "fact")
    np.testing.assert_allclose(np.asarray(tot_k), np.asarray(tot), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cnt_k), np.asarray(cnt), rtol=1e-5)


def test_score_rows_and_fresh(star, star_trees):
    sch, J, X, y = star
    trees = star_trees
    ens = compile_ensemble(sch, trees)
    tot, cnt = score_grouped(ens, "fact")
    ids = np.asarray([0, 3, 3, 17, 299])
    t2, c2 = score_rows(ens, "fact", ids)
    np.testing.assert_allclose(np.asarray(t2), np.asarray(tot)[ids])
    np.testing.assert_allclose(np.asarray(c2), np.asarray(cnt)[ids])
    mean = score_mean_rows(ens, "fact", ids)
    np.testing.assert_allclose(
        np.asarray(mean),
        np.asarray(tot)[ids] / np.maximum(np.asarray(cnt)[ids], 1.0),
        rtol=1e-6,
    )
    # fresh rows == materialized-path predictions
    feats = {c: np.asarray(J[c])[:8] for (_, c) in sch.features}
    np.testing.assert_allclose(
        np.asarray(score_fresh(ens, feats)),
        np.asarray(predict_rows(trees, X))[:8], rtol=1e-5, atol=1e-6,
    )
    with pytest.raises(KeyError):
        score_fresh(ens, {"x0": np.zeros(2)})
    # out-of-range ids must be rejected, not silently clamped by jnp.take
    for bad in ([-1], [sch.table("fact").n_rows]):
        with pytest.raises(IndexError):
            score_rows(ens, "fact", bad)


def test_booster_predict_grouped_rewired(star):
    """Booster.predict_grouped must go through the compiled scorer and
    keep the seed semantics (regression for the rewiring)."""
    sch, J, X, y = star
    b = Booster(sch, BoostConfig(n_trees=2, depth=2, mode="sketch", ssr_mode="off"))
    trees, _ = b.fit()
    tot, cnt = b.predict_grouped(trees, "fact")
    want_tot, want_cnt = _oracle(sch, J, X, trees, "fact")
    np.testing.assert_allclose(np.asarray(tot), want_tot, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(cnt), want_cnt)


# ---------------------------------------------------------------- service --

def test_lru_cache_eviction_and_stats():
    c = LRUCache(2)
    assert c.get("a") is None
    c.put("a", 1.0)
    c.put("b", 2.0)
    assert c.get("a") == 1.0         # refreshes "a"
    c.put("c", 3.0)                  # evicts "b" (LRU)
    assert c.get("b") is None
    assert c.get("a") == 1.0 and c.get("c") == 3.0
    assert c.hits == 3 and c.misses == 2 and len(c) == 2


def test_registry_versions(star, star_trees):
    sch, J, X, y = star
    reg = ModelRegistry()
    with pytest.raises(LookupError):
        reg.latest_version()
    e1 = compile_ensemble(sch, star_trees[:1])
    e2 = compile_ensemble(sch, star_trees[:2])
    v1, v2 = reg.publish(e1), reg.publish(e2)
    assert v2 > v1 and reg.latest_version() == v2
    assert reg.get()[1] is e2 and reg.get(v1)[1] is e1
    assert reg.versions() == [v1, v2]
    # bounded retention: oldest versions evict past max_versions
    small = ModelRegistry(max_versions=1)
    w1, w2 = small.publish(e1), small.publish(e2)
    assert small.versions() == [w2]
    with pytest.raises(KeyError):
        small.get(w1)


def test_service_microbatching_and_hot_swap(star, star_trees):
    sch, J, X, y = star
    trees1 = star_trees[:1]
    trees2 = star_trees
    reg = ModelRegistry()
    reg.publish(compile_ensemble(sch, trees1))
    svc = RelationalScoringService(reg, "fact", max_batch=32, max_wait_ms=5.0,
                                   cache_size=64)
    ens = compile_ensemble(sch, trees1)
    tot, cnt = score_grouped(ens, "fact")
    want = np.asarray(tot) / np.maximum(np.asarray(cnt), 1.0)

    async def run():
        with pytest.raises(RuntimeError):      # not started yet
            await svc.score(0)
        await svc.start()
        with pytest.raises(IndexError):        # bad id fails only its caller
            await svc.score(10_000)
        got = await svc.score_many(range(40))
        np.testing.assert_allclose(np.asarray(got), want[:40], rtol=1e-5)
        # second wave repeats 20 rows → pure cache hits
        rep = await svc.score_many(range(20))
        np.testing.assert_allclose(np.asarray(rep), want[:20], rtol=1e-5)

        # hot swap: v2 published mid-traffic; new requests use it
        v2 = reg.publish(compile_ensemble(sch, trees2))
        tot2, cnt2 = score_grouped(compile_ensemble(sch, trees2), "fact")
        want2 = np.asarray(tot2) / np.maximum(np.asarray(cnt2), 1.0)
        got2 = await svc.score_many(range(10))
        np.testing.assert_allclose(np.asarray(got2), want2[:10], rtol=1e-5)
        # pinned-version requests still hit v1
        got1 = await svc.score(5, version=v2 - 1)
        np.testing.assert_allclose(got1, want[5], rtol=1e-5)
        await svc.stop()
        with pytest.raises(RuntimeError):      # stopped → no silent hang
            await svc.score(0)

    asyncio.run(run())
    st = svc.stats
    assert st.requests == 71
    assert st.cache_hits >= 20                   # the repeated ids
    assert st.batches < st.requests - st.cache_hits   # coalescing happened
    assert st.mean_batch > 1.0


def test_pipeline_importance_sampling_applied():
    """Regression for the dead-code `keep` bug: one-hot weights must pin
    every produced row to the selected corpus doc, deterministically."""
    from repro.data.pipeline import TokenPipeline

    w = np.zeros(50, np.float64)
    w[7] = 1.0
    p1 = TokenPipeline(vocab=97, global_batch=4, seq_len=16, seed=3,
                       example_weights=w)
    b1 = next(p1)
    p1.stop()
    assert "doc_ids" in b1 and np.all(b1["doc_ids"] == 7)
    # same doc → same synthesized row, and the stream is reproducible
    np.testing.assert_array_equal(b1["tokens"][0], b1["tokens"][1])
    p2 = TokenPipeline(vocab=97, global_batch=4, seq_len=16, seed=3,
                       example_weights=w)
    b2 = next(p2)
    p2.stop()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    # non-degenerate weights: selection follows the distribution
    w2 = np.ones(50, np.float64)
    p3 = TokenPipeline(vocab=97, global_batch=32, seq_len=8, seed=3,
                       example_weights=w2)
    b3 = next(p3)
    p3.stop()
    assert len(np.unique(b3["doc_ids"])) > 1


# ------------------------------------------------------- transient dispatch

class _FlakySnapshotProvider:
    """Maintained-scorer stand-in whose MVCC snapshot fails the first
    ``fail_times`` dispatches (a transient tear), then heals."""

    def __init__(self, inner, fail_times=1):
        self._inner = inner
        self.fails_left = fail_times
        self.snapshot_calls = 0

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def snapshot(self, roots=()):
        self.snapshot_calls += 1
        if self.fails_left > 0:
            self.fails_left -= 1
            raise RuntimeError("transient snapshot tear (injected)")
        return self._inner.snapshot(roots=roots)


def _flaky_service(sch, trees, fail_times):
    from repro.incremental import MaintainedScorer

    ms = MaintainedScorer(compile_ensemble(sch, trees))
    ms.grouped_cached(sch.label_table)
    flaky = _FlakySnapshotProvider(ms, fail_times=fail_times)
    reg = ModelRegistry()
    reg.publish(flaky)
    svc = RelationalScoringService(reg, sch.label_table, max_batch=64,
                                   max_wait_ms=2.0, cache_size=64)
    return ms, flaky, svc


def test_service_retries_once_failing_dispatch(star, star_trees):
    """A once-failing version dispatch is re-driven after a jittered
    backoff: callers see scores, not the transient error."""
    sch, J, X, y = star
    ms, flaky, svc = _flaky_service(sch, star_trees[:2], fail_times=1)
    tot, cnt = ms.grouped_cached(sch.label_table)
    want = np.asarray(tot) / np.maximum(np.asarray(cnt), 1.0)

    async def run():
        await svc.start()
        got = await svc.score_many(range(12))
        await svc.stop()
        return got

    got = asyncio.run(run())
    np.testing.assert_allclose(np.asarray(got), want[:12], rtol=1e-5)
    assert flaky.fails_left == 0 and flaky.snapshot_calls >= 2
    assert svc.stats.retries >= 1
    assert svc.stats.errors == 0


def test_service_persistent_failure_still_errors(star, star_trees):
    """One retry, not infinite: a dispatch that keeps failing surfaces
    the error to its callers and counts in service.errors."""
    sch, J, X, y = star
    _, flaky, svc = _flaky_service(sch, star_trees[:2], fail_times=10_000)

    async def run():
        await svc.start()
        with pytest.raises(RuntimeError, match="transient snapshot tear"):
            await svc.score(0)
        await svc.stop()

    asyncio.run(run())
    assert svc.stats.errors >= 1
    assert svc.stats.retries >= 1          # it did try again first


def test_service_retry_disabled_fails_fast(star, star_trees):
    sch, J, X, y = star
    ms, flaky, _ = _flaky_service(sch, star_trees[:2], fail_times=1)
    reg = ModelRegistry()
    reg.publish(flaky)
    svc = RelationalScoringService(reg, sch.label_table, max_batch=64,
                                   max_wait_ms=2.0, retry_transient=False)

    async def run():
        await svc.start()
        with pytest.raises(RuntimeError, match="transient snapshot tear"):
            await svc.score(0)
        await svc.stop()

    asyncio.run(run())
    assert svc.stats.retries == 0
    assert svc.stats.errors >= 1
