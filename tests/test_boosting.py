"""Algorithms 1–3 end-to-end:
- exact relational training ≡ materialized-join greedy training,
- sketched training selects identical trees (paper's 'similar parameters',
  strengthened — see trainer.py docstring),
- sketched SSR within (1±ε) per grouping table (Thm 3.4),
- query-count accounting matches Thm 2.4 (O(m²L²τ)) vs Thm 3.1 (O(mLτ)).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import BoostConfig, Booster, MaterializedBooster, predict_rows
from repro.relational.generators import star_schema


def _fit_all(sch, X, y, n_trees=3, depth=3, k=256):
    cfg = BoostConfig(n_trees=n_trees, depth=depth, mode="exact")
    be = Booster(sch, cfg)
    te, tre = be.fit()
    bm = MaterializedBooster(X, y, cfg)
    tm = bm.fit()
    cfgs = BoostConfig(n_trees=n_trees, depth=depth, mode="sketch", sketch_k=k)
    bs = Booster(sch, cfgs)
    ts, trs = bs.fit()
    return (te, tre), (tm,), (ts, trs)


@pytest.fixture(scope="module")
def fitted_star(star):
    sch, J, X, y = star
    return star, _fit_all(sch, X, y)


def test_exact_equals_materialized(fitted_star):
    (sch, J, X, y), ((te, _), (tm,), _) = fitted_star
    np.testing.assert_allclose(
        np.asarray(predict_rows(te, X)), np.asarray(predict_rows(tm, X)), atol=2e-2
    )


def test_training_reduces_mse(fitted_star):
    (sch, J, X, y), ((te, _), _, _) = fitted_star
    mse = float(jnp.mean((y - predict_rows(te, X)) ** 2))
    assert mse < 0.1 * float(jnp.var(y))


def test_sketch_trees_identical(fitted_star):
    (sch, J, X, y), ((te, _), _, (ts, _)) = fitted_star
    for a, b in zip(te, ts):
        np.testing.assert_array_equal(np.asarray(a.feat), np.asarray(b.feat))
        np.testing.assert_allclose(np.asarray(a.leaf), np.asarray(b.leaf), atol=1e-4)


def test_sketch_ssr_within_eps(fitted_star):
    (sch, J, X, y), ((_, tre), _, (_, trs)) = fitted_star
    errs = []
    for e, s in zip(tre.node_ssr, trs.node_ssr):
        for tbl in e:
            if tbl == "fact":
                continue  # singleton groups → sketch exact (fanout-1 join)
            ee, ss = np.asarray(e[tbl]), np.asarray(s[tbl])
            m = ee > 1.0
            if m.any():
                errs.append((np.abs(ss - ee) / ee)[m])
    errs = np.concatenate(errs)
    assert errs.mean() < 0.2, errs.mean()


def test_fact_grouping_ssr_exact(fitted_star):
    """Fanout-1 grouping gives singleton groups: the sketched SSR must be
    *exactly* the true SSR (no collisions within a group of one)."""
    (sch, J, X, y), ((_, tre), _, (_, trs)) = fitted_star
    for e, s in zip(tre.node_ssr, trs.node_ssr):
        np.testing.assert_allclose(
            np.asarray(s["fact"]), np.asarray(e["fact"]), rtol=2e-3, atol=1e-2
        )


def test_query_complexity(star):
    """Thm 2.4 vs Thm 3.1: queries per level = τ(1+M+M²) vs τ(2+2M)."""
    sch, J, X, y = star
    tau = len(sch.tables)
    for mode, per_level in (
        ("exact", lambda M: tau * (1 + M + M * M)),
        ("sketch", lambda M: tau * (1 + M + 1 + M)),
    ):
        cfg = BoostConfig(n_trees=2, depth=2, mode=mode, sketch_k=64)
        b = Booster(sch, cfg)
        _, tr = b.fit()
        L = 2 ** cfg.depth
        want = cfg.depth * per_level(0) + cfg.depth * per_level(L)
        assert tr.queries == want, (mode, tr.queries, want)


def test_chain_exact_equals_materialized(chain):
    sch, J, X, y = chain
    cfg = BoostConfig(n_trees=2, depth=2, mode="exact")
    te, _ = Booster(sch, cfg).fit()
    tm = MaterializedBooster(X, y, cfg).fit()
    np.testing.assert_allclose(
        np.asarray(predict_rows(te, X)), np.asarray(predict_rows(tm, X)), atol=2e-2
    )


def test_ssr_mode_off_same_trees(star):
    """Production fast path (no SSR reporting) must not change the model."""
    sch, J, X, y = star
    a, _ = Booster(sch, BoostConfig(n_trees=2, depth=2, mode="sketch", ssr_mode="off")).fit()
    b, _ = Booster(sch, BoostConfig(n_trees=2, depth=2, mode="exact")).fit()
    np.testing.assert_allclose(
        np.asarray(predict_rows(a, X)), np.asarray(predict_rows(b, X)), atol=1e-4
    )


def test_sketch_ssr_envelope_across_seeds():
    """Satellite: empirical SSR error of the sketched queries vs exact
    stays within the (1+ε) envelope across PRNG seeds at the paper
    config's sketch width (Thm 3.4: (1±ε) w.p. 1−δ for k = O((2+3^τ)/
    (ε²δ))).  Empirical envelope at k=256, τ=3: ε=0.5 at δ=0.1, with a
    much tighter mean."""
    from repro.configs.paper_rbrt import CONFIG

    k = CONFIG.sketch_k                      # 256, the paper config
    errs = []
    for seed in (0, 1, 2):
        sch = star_schema(seed=seed, n_fact=150, n_dim=12)
        _, tre = Booster(sch, BoostConfig(n_trees=2, depth=2,
                                          mode="exact", seed=seed)).fit()
        _, trs = Booster(sch, BoostConfig(n_trees=2, depth=2, mode="sketch",
                                          sketch_k=k, seed=seed)).fit()
        for e, s in zip(tre.node_ssr, trs.node_ssr):
            for tbl in e:
                if tbl == "fact":
                    continue                 # singleton groups: sketch exact
                ee, ss = np.asarray(e[tbl]), np.asarray(s[tbl])
                m = ee > 1.0
                if m.any():
                    errs.append((np.abs(ss - ee) / ee)[m])
    errs = np.concatenate(errs)
    assert errs.size > 20                    # the sweep actually sampled
    assert (errs > 0.5).mean() < 0.1, errs.max()      # (1+ε) envelope, δ=0.1
    assert errs.mean() < 0.2, errs.mean()


def test_predict_grouped(star):
    """Relational scoring: per-fact-row Σŷ == brute force on J."""
    sch, J, X, y = star
    cfg = BoostConfig(n_trees=2, depth=2, mode="sketch", ssr_mode="off")
    b = Booster(sch, cfg)
    trees, _ = b.fit()
    tot, cnt = b.predict_grouped(trees, "fact")
    rows = np.asarray(J["__rows__fact"])
    preds = np.asarray(predict_rows(trees, X))
    want = np.bincount(rows, weights=preds, minlength=sch.table("fact").n_rows)
    np.testing.assert_allclose(np.asarray(tot), want, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(
        np.asarray(cnt), np.bincount(rows, minlength=sch.table("fact").n_rows)
    )
