"""MVCC snapshot isolation for concurrent ingest + serve.

Property under test: a :class:`Snapshot` is a *pin* — whatever
interleaving of ``apply()`` and snapshot reads occurs, every snapshot's
scores are bit-equal to a fresh full recompute at the snapshot's pinned
``data_version``, even long after the live state has moved on.  Plus the
serving-side guarantees built on it: version pinning at batch cutoff,
per-root staleness, deadline-aware coalescing with a clamped timeout,
queue-depth admission control, and epoch-keyed hot swaps.

Hypothesis-driven when available; the seeded sweeps keep tier-1
coverage real when it is absent (tests/_hypothesis_compat.py)."""
import asyncio
import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # pragma: no cover
    from _hypothesis_compat import given, settings, st

from repro.core import BoostConfig, Booster
from repro.incremental import MaintainedScorer, Snapshot, TableDelta
from repro.incremental.retrain import IncrementalBooster
from repro.relational.generators import (
    chain_schema, delta_stream, snowflake_schema, star_schema,
)
from repro.serving import (
    ModelRegistry, RelationalScoringService, compile_ensemble,
)
from repro.serving.service import ServiceOverloadedError


def _schema(kind, seed=11):
    if kind == "star":
        return star_schema(seed=seed, n_fact=120, n_dim=12)
    if kind == "chain":
        return chain_schema(seed=seed + 1, n_rows=60, n_tables=3, fanout=2)
    return snowflake_schema(seed=seed + 2, n_fact=80, n_dim=8, n_sub=4)


def _fit(sch, n_trees=2, depth=2):
    b = Booster(sch, BoostConfig(n_trees=n_trees, depth=depth,
                                 mode="sketch", ssr_mode="off"))
    return b.fit()[0]


def _scorer(kind, seed=11):
    sch = _schema(kind, seed)
    return sch, MaintainedScorer(compile_ensemble(sch, _fit(sch)))


def _eq(a, b):
    return np.array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------- interleaving property

def _run_interleaving(kind, seed, n_batches=5, read_stride=2):
    """Apply a delta stream while capturing oracle-pinned snapshots at
    every version; interleave reads of OLD snapshots between applies;
    then audit every snapshot — cached or re-read — bit-for-bit against
    its own pinned recompute oracle."""
    sch, ms = _scorer(kind, seed=seed)
    group = sch.label_table
    ms.grouped_cached(group)                     # warm the message cache
    snaps = [ms.snapshot(roots=(group,), pin_oracle=True)]
    for i, batch in enumerate(delta_stream(sch, ms.live_rows, seed=seed + 7,
                                           n_batches=n_batches,
                                           ops_per_batch=4)):
        ms.apply(batch)
        snaps.append(ms.snapshot(roots=(group,), pin_oracle=True))
        # interleave: re-read a historical snapshot mid-stream — the
        # read must neither see the newer version nor disturb it
        old = snaps[i // read_stride]
        t_old, c_old = old.grouped_cached(group)
        ot, oc = old.recompute_oracle(group)
        assert _eq(t_old, ot) and _eq(c_old, oc), (
            f"snapshot v{old.data_version} drifted mid-stream ({kind})")
    assert [s.data_version for s in snaps] == list(range(n_batches + 1))
    for s in snaps:
        tot, cnt = s.grouped_cached(group)
        ot, oc = s.recompute_oracle(group)
        assert _eq(tot, ot) and _eq(cnt, oc), (
            f"snapshot v{s.data_version} != oracle at its version ({kind})")
    # the live scorer itself ends bit-equal to the newest pin
    lt, lc = ms.grouped_cached(group)
    st_, sc_ = snaps[-1].grouped_cached(group)
    assert _eq(lt, st_) and _eq(lc, sc_)


@pytest.mark.parametrize("kind", ["star", "chain", "snowflake"])
def test_snapshot_reads_bit_equal_pinned_oracle(kind):
    _run_interleaving(kind, seed=11)


@given(seed=st.integers(min_value=0, max_value=2**16),
       kind=st.sampled_from(["star", "chain", "snowflake"]))
@settings(max_examples=5, deadline=None)
def test_snapshot_interleaving_property(seed, kind):
    _run_interleaving(kind, seed=seed, n_batches=3)


def test_unpinned_root_raises_and_snapshot_is_cached():
    sch, ms = _scorer("star")
    group = sch.label_table
    s = ms.snapshot(roots=(group,))
    with pytest.raises(KeyError):
        s.score_grouped("dim0")
    # one version ⇒ one shared snapshot; apply invalidates it
    assert ms.snapshot(roots=(group,)) is s
    ms.apply(next(iter(delta_stream(sch, ms.live_rows, seed=3,
                                    n_batches=1, ops_per_batch=2))))
    assert ms.snapshot(roots=(group,)) is not s


def test_snapshot_write_back_keeps_live_scorer_incremental():
    """A snapshot's lazy path-refresh must flow back to the live scorer
    when versions still agree — serving through snapshots costs no
    duplicate message emissions."""
    sch, ms = _scorer("star")
    group = sch.label_table
    ms.grouped_cached(group)
    ms.apply(next(iter(delta_stream(sch, ms.live_rows, seed=5,
                                    n_batches=1, ops_per_batch=4))))
    assert ms._dirty[group]
    snap = ms.snapshot(roots=(group,))
    snap.grouped_cached(group)                   # resolves + writes back
    assert not ms._dirty[group]
    e0 = ms.counter.edges if ms.counter else None
    ms.grouped_cached(group)                     # live read: no refresh left
    if e0 is not None:
        assert ms.counter.edges == e0


def test_concurrent_ingest_thread_vs_snapshot_reads():
    """A real writer thread races apply() against snapshot scoring; every
    result must bit-match the recompute oracle at its pinned version."""
    sch, ms = _scorer("star")
    group = sch.label_table
    ms.grouped_cached(group)
    oracles = {0: ms.snapshot(roots=(group,), pin_oracle=True)}
    n_batches = 8
    stop = threading.Event()

    def ingest():
        # lazy stream: each batch is generated against the live rows it
        # will actually apply to
        for b in delta_stream(sch, ms.live_rows, seed=9,
                              n_batches=n_batches, ops_per_batch=4):
            ms.apply(b)
            # single writer ⇒ no version can slip in before the pin
            oracles[ms.data_version] = ms.snapshot(roots=(group,),
                                                   pin_oracle=True)
            time.sleep(0.002)
        stop.set()

    results = []                                 # (snapshot, tot, cnt)
    t = threading.Thread(target=ingest)
    t.start()
    # keep reading until the writer is done AND we hold a few reads, so
    # the audit below always has material even under scheduler jitter
    while not stop.is_set() or len(results) < 3:
        s = ms.snapshot(roots=(group,))
        tot, cnt = s.score_grouped(group)
        results.append((s, tot, cnt))
    t.join()
    assert len(oracles) == n_batches + 1
    for s, tot, cnt in results:
        ot, oc = oracles[s.data_version].recompute_oracle(group)
        assert _eq(tot, ot) and _eq(cnt, oc), (
            f"torn read at data_version {s.data_version}")


# ------------------------------------------------------ per-root staleness

def test_staleness_cold_root_does_not_pin_gauge():
    """Regression: a root traffic abandoned must stop counting toward the
    aggregate staleness gauge once it leaves the served window — only
    per-root queries see its lag."""
    sch = _schema("star")
    ms = MaintainedScorer(compile_ensemble(sch, _fit(sch)),
                          served_window_s=30.0)
    hot, cold = sch.label_table, "dim0"
    ms.grouped_cached(hot)
    ms.grouped_cached(cold)                      # queried once, then abandoned
    ms.apply(next(iter(delta_stream(sch, ms.live_rows, seed=2,
                                    n_batches=1, ops_per_batch=3))))
    assert ms.staleness_s(hot) > 0 and ms.staleness_s(cold) > 0
    ms.grouped_cached(hot)                       # hot root refreshes
    assert ms.staleness_s(hot) == 0.0
    # cold root still in its served window: aggregate reflects it...
    assert ms.staleness_s() > 0.0
    # ...but once traffic has moved on (shrink the window rather than
    # sleeping — equivalent and deterministic), it must stop counting
    ms.served_window_s = 0.0
    assert ms.staleness_s() == 0.0, "cold root pinned the gauge"
    assert ms.staleness_s(cold) > 0.0            # per-root lag still visible


def test_staleness_before_any_query_counts_all_roots():
    sch, ms = _scorer("star")
    group = sch.label_table
    ms.grouped_cached(group)
    ms._last_query.clear()                       # as if nothing ever served
    ms.apply(next(iter(delta_stream(sch, ms.live_rows, seed=4,
                                    n_batches=1, ops_per_batch=2))))
    assert ms.staleness_s() > 0.0


# --------------------------------------------------- service: version pinning

def test_dispatch_pins_version_between_enqueue_and_dispatch():
    """Regression: a delta applied after enqueue but before dispatch must
    not let the batch cache fresh scores under the stale version (or
    vice versa) — the cached entry's version must match the snapshot the
    scores were computed from."""
    sch, ms = _scorer("star")
    group = sch.label_table
    ms.grouped_cached(group)
    batch = next(iter(delta_stream(sch, ms.live_rows, seed=6,
                                   n_batches=1, ops_per_batch=4)))

    async def run():
        reg = ModelRegistry()
        v = reg.publish(ms)
        svc = RelationalScoringService(reg, group, max_wait_ms=40.0)
        await svc.start()
        task = asyncio.get_running_loop().create_task(svc.score(0))
        await asyncio.sleep(0)                   # enqueued, batch still open
        ms.apply(batch)                          # data_version 0 → 1
        out = await task
        await svc.stop()
        return reg, v, svc, out

    reg, v, svc, out = asyncio.run(run())
    ep = reg.epoch(v)
    keys = list(svc.cache._d)
    assert keys == [(v, ep, 1, 0)], keys         # pinned at cutoff version
    tot, cnt = ms.snapshot(roots=(group,), pin_oracle=True).recompute_oracle(group)
    want = float(np.asarray(tot)[0]) / max(float(np.asarray(cnt)[0]), 1.0)
    assert out == want


def test_service_concurrent_ingest_cache_audit():
    """Open-loop mini version of the bench: an ingest thread applies
    deltas while the service scores; EVERY cached entry must bit-match
    the recompute oracle at the data_version in its own key."""
    sch, ms = _scorer("star")
    group = sch.label_table
    ms.grouped_cached(group)
    oracles = {0: ms.snapshot(roots=(group,), pin_oracle=True)}

    async def run():
        reg = ModelRegistry()
        v = reg.publish(ms)
        svc = RelationalScoringService(reg, group, max_batch=8,
                                       max_wait_ms=1.0, cache_size=4096)
        await svc.start()
        stop = threading.Event()

        def ingest():
            for b in delta_stream(sch, ms.live_rows, seed=8,
                                  n_batches=6, ops_per_batch=3):
                ms.apply(b)
                oracles[ms.data_version] = ms.snapshot(roots=(group,),
                                                       pin_oracle=True)
                time.sleep(0.004)
            stop.set()

        rng = np.random.default_rng(0)
        # one pre-ingest round guarantees version-0 entries in the audit
        await svc.score_many(rng.integers(0, 32, size=6).tolist())
        t = threading.Thread(target=ingest)
        t.start()
        while not stop.is_set():
            ids = rng.integers(0, 32, size=6).tolist()
            await svc.score_many(ids)
        t.join()
        # one post-ingest round guarantees final-version entries too
        await svc.score_many(rng.integers(0, 32, size=6).tolist())
        await svc.stop()
        return reg, v, svc

    reg, v, svc = asyncio.run(run())
    assert len(svc.cache) > 0
    means = {}
    for (kv, ep, dv, row), val in svc.cache._d.items():
        assert kv == v and ep == reg.epoch(v)
        if dv not in means:
            tot, cnt = oracles[dv].recompute_oracle(group)
            means[dv] = (np.asarray(tot),
                         np.maximum(np.asarray(cnt), 1.0))
        tot, cnt = means[dv]
        assert val == float(tot[row]) / float(cnt[row]), (
            f"cache entry at v{dv} row {row} does not match its pinned oracle")
    assert len(means) > 1                        # audit spanned versions


# ------------------------------------------- service: deadline & backpressure

def test_flood_past_max_wait_clamps_timeout():
    """Flooding the queue far past the coalescing window must never feed
    asyncio.wait_for a negative timeout — every request resolves, none
    error out."""
    sch, ms = _scorer("star")
    group = sch.label_table

    async def run():
        reg = ModelRegistry()
        reg.publish(ms)
        svc = RelationalScoringService(reg, group, max_batch=4,
                                       max_wait_ms=0.01, cache_size=0,
                                       latency_budget_ms=0.02)
        await svc.start()
        outs = await svc.score_many(list(range(64)) * 3)
        await svc.stop()
        return svc, outs

    svc, outs = asyncio.run(run())
    assert len(outs) == 192 and all(isinstance(o, float) for o in outs)
    assert svc.stats.errors == 0
    assert svc.stats.batches >= 192 // 4


def test_deadline_cutoff_beats_max_wait():
    """With a tight latency budget the batcher must close the window at
    the deadline cutoff, not sit out a huge max_wait."""
    sch, ms = _scorer("star")
    group = sch.label_table

    async def run():
        reg = ModelRegistry()
        reg.publish(ms)
        svc = RelationalScoringService(reg, group, max_wait_ms=2000.0,
                                       latency_budget_ms=50.0,
                                       deadline_frac=0.5, cache_size=0)
        await svc.start()
        t0 = time.perf_counter()
        await svc.score(0)
        dt = time.perf_counter() - t0
        await svc.stop()
        return dt

    dt = asyncio.run(run())
    assert dt < 1.0, f"request waited {dt:.3f}s — deadline cutoff ignored"


def test_queue_depth_admission_control_sheds():
    sch, ms = _scorer("star")
    group = sch.label_table

    class Burning:                               # SLO stub: always degraded
        def state(self):
            return "degraded"

        def record_latency(self, ms):
            pass

        def record_request(self, error=False):
            pass

        def set_staleness(self, s):
            pass

    async def run():
        reg = ModelRegistry()
        reg.publish(ms)
        svc = RelationalScoringService(reg, group, max_batch=1,
                                       max_wait_ms=0.0, cache_size=0,
                                       slo=Burning(), max_queue=4)
        await svc.start()
        results = await asyncio.gather(
            *(svc.score(i % 16) for i in range(64)), return_exceptions=True)
        await svc.stop()
        return svc, results

    svc, results = asyncio.run(run())
    shed = [r for r in results if isinstance(r, ServiceOverloadedError)]
    ok = [r for r in results if isinstance(r, float)]
    assert shed and ok and len(shed) + len(ok) == 64
    assert svc.stats.shed == len(shed)


# --------------------------------------------------- registry: epoch & swap

def test_hot_swap_same_slot_does_not_collide_in_cache():
    """Regression: two static models both report data_version 0; after an
    in-place swap the service must serve the NEW model's scores, not the
    old occupant's cached ones."""
    sch = _schema("star")
    ens_a = compile_ensemble(sch, _fit(sch, n_trees=2))
    ens_b = compile_ensemble(sch, _fit(sch, n_trees=3))
    assert ens_a.data_version == ens_b.data_version == 0
    group = sch.label_table

    def direct(ens, row):
        from repro.serving.scorer import score_mean_rows
        return float(np.asarray(
            score_mean_rows(ens, group, np.asarray([row], np.int32)))[0])

    async def run():
        reg = ModelRegistry()
        v = reg.publish(ens_a)
        svc = RelationalScoringService(reg, group, max_wait_ms=0.1)
        await svc.start()
        a = await svc.score(0)
        reg.swap(v, ens_b)
        b = await svc.score(0)
        await svc.stop()
        return a, b

    a, b = asyncio.run(run())
    assert a == direct(ens_a, 0)
    assert b == direct(ens_b, 0), "swap served the old occupant's cache"
    assert a != b                                # distinct models, really


def test_stacked_cache_tracks_swap_epoch():
    sch = _schema("star")
    ens_a = compile_ensemble(sch, _fit(sch, n_trees=2))
    ens_b = compile_ensemble(sch, _fit(sch, n_trees=3))
    group = sch.label_table
    reg = ModelRegistry()
    v = reg.publish(ens_a)
    s1 = reg.stacked()
    (ta, _), = s1.score_grouped(group)
    reg.swap(v, ens_b)
    s2 = reg.stacked()
    assert s2 is not s1, "stacked cache survived a hot swap"
    (tb, _), = s2.score_grouped(group)
    assert not _eq(ta, tb)


def test_stacked_pins_constituent_data_versions():
    sch = _schema("star")
    reg = ModelRegistry()
    reg.publish(compile_ensemble(sch, _fit(sch, n_trees=2)))
    st_ = reg.stacked()
    assert st_.data_versions == (0,)


# --------------------------------------------------- booster publish surface

def test_incremental_booster_compile_snapshot_pins_version():
    sch = _schema("star")
    cfg = BoostConfig(n_trees=2, depth=2, mode="sketch", ssr_mode="off")
    ib = IncrementalBooster(sch, cfg)
    ib.fit()
    for batch in delta_stream(sch, ib.live_rows, seed=3, n_batches=2,
                              ops_per_batch=3):
        ib.apply(batch)
    snap = ib.compile_snapshot()
    assert snap.data_version == ib.state.data_version > 0
    # the artifact is static: registry-publishable and stackable
    reg = ModelRegistry()
    reg.publish(snap)
    (tot, cnt), = reg.stacked().score_grouped(sch.label_table)
    assert tot.shape[0] == cnt.shape[0] > 0


# --------------------------------------------------------------- snapshot GC

def test_snapshot_gc_bounds_cache_and_long_pin_stays_servable():
    """A snapshot handle held across many applies must keep serving its
    pinned version bit-exactly even after the scorer's version cache has
    GC'd it; the cache itself stays bounded by ``snapshot_retention``."""
    sch = _schema("star")
    ms = MaintainedScorer(compile_ensemble(sch, _fit(sch)),
                          snapshot_retention=3)
    group = sch.label_table
    ms.grouped_cached(group)
    pinned = ms.snapshot(roots=(group,), pin_oracle=True)
    v0 = pinned.data_version
    ot, oc = pinned.recompute_oracle(group)      # oracle pinned at v0

    for batch in delta_stream(sch, ms.live_rows, seed=21,
                              n_batches=8, ops_per_batch=3):
        ms.apply(batch)
        ms.snapshot(roots=(group,))              # one pin per version

    # the per-version cache is bounded and the old version was evicted…
    assert len(ms._snaps) <= ms.snapshot_retention
    assert v0 not in ms._snaps
    assert min(ms._snaps) > ms.data_version - ms.snapshot_retention
    # …but the long-held handle is self-contained: still bit-equal to
    # the oracle recomputed at ITS version, untouched by 8 newer applies
    t_old, c_old = pinned.grouped_cached(group)
    assert _eq(t_old, ot) and _eq(c_old, oc)
    # a fresh snapshot at the live version still round-trips
    live = ms.snapshot(roots=(group,), pin_oracle=True)
    lt, lc = live.grouped_cached(group)
    lo_t, lo_c = live.recompute_oracle(group)
    assert _eq(lt, lo_t) and _eq(lc, lo_c)
    # GC publishes its pressure gauges
    from repro.obs import get_registry
    snap = get_registry().snapshot()
    assert snap["snapshot.pinned_versions"]["value"] == len(ms._snaps)
    assert snap["snapshot.oldest_pin_age_s"]["value"] >= 0.0
