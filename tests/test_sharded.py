"""Sharded-vs-single-device bit-equality properties.

The mesh-sharded SumProd must be a pure *placement* change: scores,
trees, and delta-refreshed results bit-equal to the single-device run,
and the host-side query/edge accounting untouched.  The compiled
factors carry integer-valued counts, and the training properties pin
labels to a dyadic grid (multiples of 1/16), so every cross-shard ⊕
re-association is exact in f32 — bit-equality is the spec here, not a
tolerance.

Single-device identity properties always run (tier-1).  The
multi-device properties need forced host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m pytest tests/test_sharded.py
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.schema as S
from repro.core import BoostConfig, Booster, QueryCounter
from repro.distributed import spmd
from repro.incremental import MaintainedScorer
from repro.incremental.retrain import IncrementalBooster
from repro.launch.mesh import make_data_mesh
from repro.relational import generators
from repro.serving import compile_ensemble
from repro.serving.scorer import score_grouped

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs forced host devices: "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def _quantize_labels(sch):
    """Snap labels to multiples of 1/16 so the label/label² sums the
    trainer reduces are exactly representable — cross-shard ⊕ becomes
    associative in f32 and bit-equality is well-defined."""
    lt, lc = sch.label_table, sch.label_column
    tabs = []
    for t in sch.tables:
        cols = dict(t.columns)
        if t.name == lt:
            cols[lc] = np.round(np.asarray(cols[lc]) * 16.0) / 16.0
        tabs.append(S.Table(t.name, cols))
    return S.Schema(tabs, label=(lt, lc))


def _quantize_delta(sch, batch):
    """Same 1/16 grid for labels arriving THROUGH the delta stream —
    an inserted/updated row with an arbitrary float label would break
    the dyadic exactness the bit-equality property rests on."""
    from repro.incremental import TableDelta
    lt, lc = sch.label_table, sch.label_column
    out = []
    for d in batch:
        if d.table != lt:
            out.append(d)
            continue
        ins, upd = d.inserts, d.updates
        if ins and lc in ins:
            ins = dict(ins)
            ins[lc] = np.round(np.asarray(ins[lc]) * 16.0) / 16.0
        if upd and lc in upd[1]:
            cols = dict(upd[1])
            cols[lc] = np.round(np.asarray(cols[lc]) * 16.0) / 16.0
            upd = (upd[0], cols)
        out.append(TableDelta(d.table, inserts=ins, deletes=d.deletes,
                              updates=upd))
    return out


def _schema(kind):
    if kind == "star":          # n_fact % 8 == 0 → factors really shard
        return generators.star_schema(seed=3, n_fact=512, n_dim=24)
    if kind == "chain":
        return generators.chain_schema(seed=9, n_rows=256)
    return generators.snowflake_schema(seed=7, n_fact=256, n_dim=16)


def _trees_equal(ts1, ts2):
    return len(ts1) == len(ts2) and all(
        jnp.array_equal(a.feat, b.feat) and jnp.array_equal(a.thr, b.thr)
        and jnp.array_equal(a.leaf, b.leaf)
        for a, b in zip(ts1, ts2))


# ---------------------------------------------------------------- identity

def test_no_mesh_helpers_are_identity():
    x = jnp.arange(24.0).reshape(8, 3)
    assert spmd.current_data_mesh() is None
    assert spmd.data_axis_size() == 1
    assert spmd.mesh_fingerprint() is None
    assert spmd.shard_rows(x) is x
    assert spmd.psum_message(x) is x
    assert spmd.replicate(x) is x
    assert spmd.constrain_rows(x) is x


def test_mesh_of_one_resolves_to_no_mesh():
    mesh = make_data_mesh(1)
    with spmd.use_data_mesh(mesh):
        assert spmd.data_axis_size() == 1
        x = jnp.ones((8, 2))
        assert spmd.shard_rows(x) is x


def test_single_device_scoring_unchanged_under_mesh_context():
    sch = _schema("star")
    cfg = BoostConfig(n_trees=2, depth=2, mode="sketch", ssr_mode="off")
    trees, _ = Booster(sch, cfg).fit()
    t1, n1 = score_grouped(compile_ensemble(sch, trees), sch.label_table)
    with spmd.use_data_mesh(make_data_mesh(1)):
        ens = compile_ensemble(sch, trees)
    t2, n2 = score_grouped(ens, sch.label_table)
    assert jnp.array_equal(t1, t2) and jnp.array_equal(n1, n2)


# ------------------------------------------------------------ multi-device

@multidevice
@pytest.mark.parametrize("kind", ["star", "chain", "snowflake"])
def test_sharded_grouped_scores_bit_equal(kind):
    sch = _schema(kind)
    group = sch.label_table
    cfg = BoostConfig(n_trees=3, depth=3, mode="sketch", ssr_mode="off",
                      seed=0)
    trees, _ = Booster(sch, cfg).fit()

    c1 = QueryCounter()
    t1, n1 = score_grouped(compile_ensemble(sch, trees, counter=c1), group)

    mesh = make_data_mesh()
    cN = QueryCounter()
    with spmd.use_data_mesh(mesh):
        ensN = compile_ensemble(sch, trees, counter=cN)
    if kind == "star":          # 512 % 8 == 0: placement must be real
        assert spmd.is_row_sharded(ensN.factors["fact"], mesh)
    tN, nN = score_grouped(ensN, group)

    assert jnp.array_equal(t1, tN) and jnp.array_equal(n1, nN)
    assert c1.edges == cN.edges and c1.count == cN.count


@multidevice
@pytest.mark.parametrize("kind", ["star", "chain", "snowflake"])
def test_sharded_training_trees_bit_equal(kind):
    sch = _quantize_labels(_schema(kind))
    cfg = BoostConfig(n_trees=3, depth=3, mode="exact", ssr_mode="per_table",
                      seed=0)

    b1 = Booster(sch, cfg)
    trees1, _ = b1.fit()

    with spmd.use_data_mesh(make_data_mesh()):
        bN = Booster(sch, cfg)
        treesN, _ = bN.fit()

    assert _trees_equal(trees1, treesN)
    assert b1.counter.edges == bN.counter.edges


@multidevice
@pytest.mark.parametrize("kind", ["star", "snowflake"])
def test_sharded_delta_refresh_bit_equal(kind):
    """Insert/delete/update stream through MaintainedScorer: the
    path-restricted refresh must stay bit-equal shard-by-shard."""
    sch = _quantize_labels(_schema(kind))
    group = sch.label_table
    cfg = BoostConfig(n_trees=3, depth=3, mode="sketch", ssr_mode="off",
                      seed=0)
    trees, _ = Booster(sch, cfg).fit()

    def run(mesh):
        with spmd.use_data_mesh(mesh):
            c = QueryCounter()
            ms = MaintainedScorer(compile_ensemble(sch, trees), counter=c)
        outs = [ms.grouped_cached(group)]
        # regenerated per run: both scorers' live-row states evolve
        # identically, so the same seed yields the same stream
        for batch in generators.delta_stream(sch, ms.live_rows, seed=4,
                                             n_batches=6, ops_per_batch=8):
            ms.apply(batch)
            outs.append(ms.grouped_cached(group))
        return outs, c.edges

    o1, e1 = run(None)
    oN, eN = run(make_data_mesh())
    for (t1, n1), (tN, nN) in zip(o1, oN):
        assert jnp.array_equal(t1, tN) and jnp.array_equal(n1, nN)
    assert e1 == eN


@multidevice
@pytest.mark.parametrize("kind", ["star", "snowflake"])
def test_sharded_snapshot_reads_bit_equal(kind):
    """MVCC snapshots under a data mesh: reads served from a pinned
    ``Snapshot`` (lazy path-restricted refresh + write-back) must be
    bit-equal to the single-device run at every data_version, and each
    must match its own pinned single-device recompute oracle — snapshot
    isolation is a concurrency feature, not a numerics fork."""
    sch = _quantize_labels(_schema(kind))
    group = sch.label_table
    cfg = BoostConfig(n_trees=3, depth=3, mode="sketch", ssr_mode="off",
                      seed=0)
    trees, _ = Booster(sch, cfg).fit()

    def run(mesh):
        with spmd.use_data_mesh(mesh):
            ms = MaintainedScorer(compile_ensemble(sch, trees))
        outs = []
        snap = ms.snapshot(roots=(group,), pin_oracle=True)
        outs.append((snap.score_grouped(group), snap.recompute_oracle(group)))
        for batch in generators.delta_stream(sch, ms.live_rows, seed=4,
                                             n_batches=4, ops_per_batch=8):
            ms.apply(batch)
            snap = ms.snapshot(roots=(group,), pin_oracle=True)
            outs.append((snap.score_grouped(group),
                         snap.recompute_oracle(group)))
        return outs

    o1 = run(None)
    oN = run(make_data_mesh())
    for ((t1, n1), (ot1, on1)), ((tN, nN), (otN, onN)) in zip(o1, oN):
        assert jnp.array_equal(t1, tN) and jnp.array_equal(n1, nN)
        # the oracle is pinned single-device inside _oracle_from, so it
        # must agree across runs AND with the snapshot reads themselves
        assert jnp.array_equal(ot1, otN) and jnp.array_equal(on1, onN)
        assert jnp.array_equal(t1, ot1) and jnp.array_equal(n1, on1)


@multidevice
def test_sharded_warm_start_refit_bit_equal():
    sch = _quantize_labels(_schema("star"))
    cfg = BoostConfig(n_trees=3, depth=3, mode="sketch", ssr_mode="off",
                      seed=0)

    def run(mesh):
        with spmd.use_data_mesh(mesh):
            ib = IncrementalBooster(sch, cfg)
        ib.fit()
        for batch in generators.delta_stream(sch, ib.live_rows, seed=11,
                                             n_batches=3, ops_per_batch=6):
            ib.refit(deltas=_quantize_delta(sch, batch), n_new_trees=1,
                     drift_threshold=-1.0)
        return ib.trees, ib.counter.edges

    t1, e1 = run(None)
    tN, eN = run(make_data_mesh())
    assert _trees_equal(t1, tN)
    assert e1 == eN
