"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here —
smoke tests and benchmarks must see the single real CPU device; only
launch/dryrun.py forces 512 placeholder devices (in its own process).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import materialize_join
from repro.relational.generators import chain_schema, snowflake_schema, star_schema


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavyweight test deselected by default (pytest.ini addopts); "
        "run the full suite with `pytest -m \"\"`",
    )


@pytest.fixture(scope="session")
def star():
    sch = star_schema(seed=5, n_fact=300, n_dim=24)
    J = materialize_join(sch)
    X = jnp.stack([J[c] for (_, c) in sch.features], axis=1)
    y = J[sch.label_column]
    return sch, J, X, y


@pytest.fixture(scope="session")
def chain():
    sch = chain_schema(seed=9, n_rows=128, n_tables=3, fanout=3)
    J = materialize_join(sch)
    X = jnp.stack([J[c] for (_, c) in sch.features], axis=1)
    y = J[sch.label_column]
    return sch, J, X, y


@pytest.fixture(scope="session")
def snowflake():
    sch = snowflake_schema(seed=3, n_fact=200, n_dim=16, n_sub=4)
    J = materialize_join(sch)
    X = jnp.stack([J[c] for (_, c) in sch.features], axis=1)
    y = J[sch.label_column]
    return sch, J, X, y
