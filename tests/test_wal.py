"""WAL codec + replay invariants: encode/decode identity, LSN
monotonicity, torn-tail semantics, and the core durability property —
replay of ANY prefix of a logged delta stream bit-equals both a scorer
that applied the same prefix directly and the full-recompute oracle.

Property-based via hypothesis where available (seeded example loops
otherwise — see tests/_hypothesis_compat.py)."""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # pragma: no cover
    from _hypothesis_compat import given, settings, st

from repro.core import Booster, BoostConfig
from repro.incremental import MaintainedScorer, TableDelta
from repro.incremental.wal import (
    MAGIC, WalCorruptError, WalFollower, WalReader, WalWriter,
    decode_record, encode_record, read_records, scan_wal, wal_path,
)
from repro.relational.generators import (
    chain_schema, delta_stream, snowflake_schema, star_schema,
)
from repro.serving import compile_ensemble


def _fit(sch, n_trees=2, depth=2):
    b = Booster(sch, BoostConfig(n_trees=n_trees, depth=depth,
                                 mode="sketch", ssr_mode="off"))
    return b.fit()[0]


def _small(shape):
    if shape == "star":
        return star_schema(seed=11, n_fact=120, n_dim=12)
    if shape == "chain":
        return chain_schema(seed=12, n_rows=60, n_tables=3, fanout=2)
    return snowflake_schema(seed=13, n_fact=80, n_dim=8, n_sub=4)


def _arrays_equal(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return (a.dtype == b.dtype and a.shape == b.shape
            and a.tobytes() == b.tobytes())      # bitwise: NaNs compare too


def _deltas_equal(xs, ys) -> bool:
    if len(xs) != len(ys):
        return False
    for x, y in zip(xs, ys):
        if x.table != y.table:
            return False
        if (x.inserts is None) != (y.inserts is None):
            return False
        if x.inserts is not None:
            if set(x.inserts) != set(y.inserts):
                return False
            if not all(_arrays_equal(v, y.inserts[c])
                       for c, v in x.inserts.items()):
                return False
        if (x.deletes is None) != (y.deletes is None):
            return False
        if x.deletes is not None and not _arrays_equal(x.deletes, y.deletes):
            return False
        if (x.updates is None) != (y.updates is None):
            return False
        if x.updates is not None:
            if not _arrays_equal(x.updates[0], y.updates[0]):
                return False
            if set(x.updates[1]) != set(y.updates[1]):
                return False
            if not all(_arrays_equal(v, y.updates[1][c])
                       for c, v in x.updates[1].items()):
                return False
    return True


def _random_delta(rng) -> TableDelta:
    dtypes = [np.float32, np.float64, np.int64, np.int32]
    ins = dele = upd = None
    if rng.random() < 0.7:
        k = int(rng.integers(1, 5))
        ins = {f"c{i}": rng.standard_normal(k).astype(rng.choice(dtypes))
               for i in range(int(rng.integers(1, 4)))}
    if rng.random() < 0.5:
        dele = rng.integers(0, 1000, int(rng.integers(1, 6))).astype(np.int64)
    if rng.random() < 0.5:
        k = int(rng.integers(1, 4))
        upd = (rng.integers(0, 1000, k).astype(np.int64),
               {f"u{i}": rng.standard_normal(k).astype(rng.choice(dtypes))
                for i in range(int(rng.integers(1, 3)))})
    return TableDelta(table=f"t{int(rng.integers(3))}", inserts=ins,
                      deletes=dele, updates=upd)


# ------------------------------------------------------------------- codec --

def test_record_roundtrip_identity_seeded():
    """Seeded sweep: encode→decode reproduces every array bit-for-bit,
    dtype and shape included."""
    rng = np.random.default_rng(0)
    for lsn in range(1, 60):
        deltas = [_random_delta(rng) for _ in range(int(rng.integers(1, 4)))]
        lsn2, out, tw = decode_record(encode_record(lsn, deltas, t_wall=123.5))
        assert lsn2 == lsn
        assert tw == 123.5
        assert _deltas_equal(deltas, out)


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=2**31),
       st.lists(st.integers(min_value=0, max_value=255),
                min_size=0, max_size=32),
       st.lists(st.floats(width=32, allow_nan=True), min_size=1, max_size=16))
def test_record_roundtrip_identity_property(lsn, dele, vals):
    """Property: roundtrip identity holds for arbitrary payloads,
    including NaN floats (bitwise compare) and empty delete sets."""
    deltas = [TableDelta(
        table="t",
        inserts={"a": np.asarray(vals, np.float32),
                 "b": np.arange(len(vals), dtype=np.int64)},
        deletes=np.asarray(dele, np.int64) if dele else None,
    )]
    lsn2, out, _ = decode_record(encode_record(lsn, deltas))
    assert lsn2 == lsn
    assert _deltas_equal(deltas, out)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=6),
                min_size=1, max_size=20))
def test_lsn_monotonic_property(sizes, tmp_path_factory):
    """Property: whatever batch sizes arrive, the log carries strictly
    consecutive LSNs and the writer refuses any other sequence."""
    d = str(tmp_path_factory.mktemp("walp"))
    w = WalWriter(d, sync_every=4)
    rng = np.random.default_rng(1)
    for i, k in enumerate(sizes, start=1):
        w.append(i, [_random_delta(rng) for _ in range(k)])
    with pytest.raises(ValueError):
        w.append(len(sizes) + 2, [])      # gap
    with pytest.raises(ValueError):
        w.append(len(sizes), [])          # repeat
    w.close()
    lsns = [l for l, _, _, _ in read_records(wal_path(d))]
    assert lsns == list(range(1, len(sizes) + 1))


# ----------------------------------------------------------------- writer --

def test_writer_refuses_non_monotonic_and_scan_ignores_heartbeats(tmp_path):
    w = WalWriter(str(tmp_path), sync_every=1)
    rng = np.random.default_rng(2)
    w.append(1, [_random_delta(rng)])
    w.heartbeat()
    w.append(2, [_random_delta(rng)])
    w.heartbeat()
    w.close()
    last, end, size = scan_wal(wal_path(str(tmp_path)))
    assert last == 2
    assert end == size                    # heartbeats are valid records
    r = WalReader(str(tmp_path))
    recs = r.poll()
    assert [l for l, _, _ in recs] == [1, 0, 2, 0]
    assert r.poll() == []                 # tail consumed, nothing new


def test_torn_tail_is_clean_stop_and_midlog_damage_raises(tmp_path):
    d = str(tmp_path)
    w = WalWriter(d, sync_every=1)
    rng = np.random.default_rng(3)
    for i in range(1, 5):
        w.append(i, [_random_delta(rng)])
    w.close()
    path = wal_path(d)
    good = os.path.getsize(path)
    # torn tail: a partial record is a clean stop at lsn 4
    with open(path, "ab") as f:
        f.write(b"\x07\x00\x00\x00garbage")
    lsns = [l for l, _, _, _ in read_records(path)]
    assert lsns == [1, 2, 3, 4]
    last, end, size = scan_wal(path)
    assert (last, end) == (4, good) and size > good
    # a fresh writer refuses the damaged log unless asked to repair
    with pytest.raises(WalCorruptError):
        WalWriter(d, sync_every=1)
    w2 = WalWriter(d, sync_every=1, repair=True)
    assert w2.last_lsn == 4
    assert os.path.getsize(path) == good
    w2.append(5, [_random_delta(rng)])
    w2.close()
    # mid-log damage (NOT at the tail) must raise, never skip silently
    with open(path, "r+b") as f:
        f.seek(good - 3)
        b = f.read(1)
        f.seek(good - 3)
        f.write(bytes([b[0] ^ 0x10]))
    with pytest.raises(WalCorruptError):
        list(read_records(path))


# ----------------------------------------------------------------- replay --

@pytest.mark.parametrize("shape", ["star", "chain", "snowflake"])
def test_prefix_replay_bit_equals_direct_apply_and_oracle(shape):
    """THE durability property: replaying any prefix of the log into a
    fresh scorer bit-equals a scorer that applied the same prefix
    directly; the full replay also bit-equals the recompute oracle."""
    sch = _small(shape)
    trees = _fit(sch)
    root = sch.tables[0].name

    ms = MaintainedScorer(compile_ensemble(sch, trees))
    wdir = None
    import tempfile
    wdir = tempfile.mkdtemp()
    w = WalWriter(wdir, sync_every=1).attach(ms.state)
    refs = []                            # (tot, cnt) after each batch
    for batch in delta_stream(sch, ms.live_rows, seed=17, n_batches=5,
                              ops_per_batch=5):
        ms.apply(batch)
        refs.append(tuple(np.asarray(a) for a in ms.score_grouped(root)))
    w.close()
    n = len(refs)

    records = [(l, ds) for l, ds, _, _ in read_records(wal_path(wdir))]
    assert [l for l, _ in records] == list(range(1, n + 1))

    for k in sorted({1, (n + 1) // 2, n}):
        ms2 = MaintainedScorer(compile_ensemble(sch, trees))
        for _, ds in records[:k]:
            ms2.apply(ds)
        assert ms2.data_version == k
        tot, cnt = (np.asarray(a) for a in ms2.score_grouped(root))
        assert _arrays_equal(tot, refs[k - 1][0])
        assert _arrays_equal(cnt, refs[k - 1][1])
        if k == n:
            ot, oc = (np.asarray(a) for a in ms2.recompute_oracle(root))
            assert _arrays_equal(tot, ot)
            assert _arrays_equal(cnt, oc)
    import shutil
    shutil.rmtree(wdir)


def test_follower_tails_and_reports_lag(tmp_path):
    """A follower applies records in LSN order as they land, skips
    heartbeats, and reports zero lag once drained."""
    d = str(tmp_path)
    sch = _small("star")
    trees = _fit(sch)
    ms = MaintainedScorer(compile_ensemble(sch, trees))
    w = WalWriter(d, sync_every=1).attach(ms.state)

    replica = MaintainedScorer(compile_ensemble(sch, trees))
    fol = WalFollower(d, replica.apply, poll_interval_s=0.001)

    batches = list(delta_stream(sch, ms.live_rows, seed=29, n_batches=4,
                                ops_per_batch=4))
    ms.apply(batches[0])
    w.heartbeat()
    assert fol.step() == 1
    assert fol.applied_lsn == 1
    assert fol.replication_lag_s() == 0.0
    assert fol.writer_idle_s() >= 0.0
    for b in batches[1:]:
        ms.apply(b)
    w.close()
    fol.step()
    assert fol.applied_lsn == ms.data_version == len(batches)
    root = sch.tables[0].name
    a = tuple(np.asarray(x) for x in ms.score_grouped(root))
    b = tuple(np.asarray(x) for x in replica.score_grouped(root))
    assert _arrays_equal(a[0], b[0]) and _arrays_equal(a[1], b[1])
