"""Production substrate: checkpoint/restore (+elastic reshard in a
subprocess with a different device count), watchdog/straggler, retries,
data-pipeline determinism, count-sketch gradient compression, and the
row-sharded SumProd (runs in a subprocess with 8 placeholder devices)."""
import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import Checkpointer
from repro.runtime.fault import FaultInjector, StepWatchdog, run_with_retries


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones((5,), jnp.bfloat16)}}
    ck.save(7, tree, blocking=True)
    assert ck.latest_step() == 7
    back = ck.restore(7, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert back["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((64, 64))}
    for s in (1, 2, 3, 4):
        ck.save(s, jax.tree.map(lambda a: a + s, tree))
    ck.wait()
    assert sorted(ck.all_steps()) == [3, 4]
    back = ck.restore(4, tree)
    assert float(back["x"][0, 0]) == 4.0


def test_elastic_restore_other_device_count(tmp_path):
    """Save here (1 device), restore in a subprocess with 8 devices onto a
    (4,2) mesh with real shardings — the elastic-downscale path."""
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(64.0 * 32).reshape(64, 32)}
    ck.save(3, tree, blocking=True)
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import Checkpointer
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ck = Checkpointer({str(tmp_path)!r})
        like = {{"w": jnp.zeros((64, 32))}}
        sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
        out = ck.restore(3, like, sh)
        assert out["w"].sharding.spec == P("data", "model"), out["w"].sharding
        np.testing.assert_array_equal(
            np.asarray(out["w"]), np.arange(64.0*32).reshape(64, 32))
        print("ELASTIC_OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, cwd=os.getcwd(), timeout=300)
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(threshold=2.0, warmup=2)
    for s in range(6):
        wd.observe(s, 0.10)
    assert wd.observe(6, 0.5)
    assert wd.straggler_steps == [6]
    assert not wd.observe(7, 0.11)


def test_retries_then_success():
    inj = FaultInjector([0])
    calls = []

    def step(state, batch):
        inj.maybe_fail(0)
        calls.append(1)
        return state + batch

    out = run_with_retries(step, 1, 2, retries=2)
    assert out == 3 and len(calls) == 1


def test_retries_exhausted():
    def step(state, batch):
        raise RuntimeError("dead device")

    with pytest.raises(RuntimeError):
        run_with_retries(step, 0, 0, retries=1)


def test_pipeline_deterministic_and_reassign():
    from repro.data.pipeline import TokenPipeline

    def grab(pipe, n):
        return [next(pipe) for _ in range(n)]

    p1 = TokenPipeline(vocab=97, global_batch=8, seq_len=16, seed=5)
    a = grab(p1, 3)
    p1.stop()
    p2 = TokenPipeline(vocab=97, global_batch=8, seq_len=16, seed=5)
    b = grab(p2, 3)
    p2.stop()
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])

    p3 = TokenPipeline(vocab=97, global_batch=12, seq_len=16, seed=5,
                       n_hosts=4, host_id=0)
    n0 = next(p3)["tokens"].shape[0]
    p3.reassign(3)          # host 3 went slow/dead
    p3.seek(100)
    n1 = next(p3)["tokens"].shape[0]
    p3.stop()
    assert n0 == 3 and n1 == 4, (n0, n1)  # remaining hosts absorb the shard


@pytest.mark.slow
def test_grad_compression_unbiased_and_converges():
    from repro.optim.grad_compress import CountSketchCompressor

    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(4096), jnp.float32)}
    # unbiasedness across hash draws
    ests = []
    for s in range(24):
        c = CountSketchCompressor(ratio=8, seed=s, error_feedback=False)
        ests.append(np.asarray(c(g)["w"]))
    err = np.abs(np.mean(ests, 0) - np.asarray(g["w"])).mean()
    assert err < 0.45, err

    # error feedback: quadratic toy problem still converges
    w_true = jnp.asarray(rng.standard_normal(512), jnp.float32)
    w = jnp.zeros(512)
    comp = CountSketchCompressor(ratio=8, seed=1)
    for _ in range(400):
        grad = {"w": w - w_true}
        w = w - 0.1 * comp(grad)["w"]
    final = float(jnp.linalg.norm(w - w_true) / jnp.linalg.norm(w_true))
    assert final < 0.05, final
    assert comp.compressed_bytes({"w": w}) <= 512 * 4 / 4  # ≥4× smaller


@pytest.mark.slow
def test_sharded_sumprod_subprocess():
    """Row-sharded inside-out == single-device engine (8 devices, star +
    chain schemas, arithmetic/channels/tropical)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import Arithmetic, Channels, Tropical, SumProd
        from repro.distributed.collectives import ShardedSumProd
        from repro.relational.generators import star_schema, chain_schema
        mesh = jax.make_mesh((8,), ("data",))
        for sch in (star_schema(seed=2, n_fact=203, n_dim=17),
                    chain_schema(seed=3, n_rows=67, n_tables=3, fanout=3)):
            ssp = ShardedSumProd(sch, mesh)
            sp = SumProd(sch)
            c3 = Channels(3)
            f = sp.ones_factors(c3)
            lbl = sch.labels
            f[sch.label_table] = jnp.stack([jnp.ones_like(lbl), lbl, lbl**2], -1)
            for tbl in [t.name for t in sch.tables]:
                got = ssp(c3, f, group_by=tbl)
                want = sp(c3, f, group_by=tbl)
                np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                           rtol=1e-4, atol=1e-4)
            tr = Tropical()
            ftr = {t.name: jnp.asarray(
                np.random.default_rng(1).standard_normal(t.n_rows), jnp.float32)
                for t in sch.tables}
            got = ssp(tr, ftr, group_by=sch.tables[0].name)
            want = sp(tr, ftr, group_by=sch.tables[0].name)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)
        print("SHARDED_OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, cwd=os.getcwd(), timeout=600)
    assert "SHARDED_OK" in r.stdout, r.stderr[-3000:]


@pytest.mark.slow
def test_train_driver_checkpoint_resume(tmp_path):
    """End-to-end driver twice: run 6 steps with a checkpoint at 4, then
    resume from 4 and confirm continuation (production restart path)."""
    from repro.launch import train as train_mod

    args = ["--arch", "tinyllama_1_1b", "--steps", "6", "--batch", "4",
            "--seq", "32", "--n-micro", "2", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "4", "--log-every", "2"]
    train_mod.main(args)
    from repro.checkpoint import Checkpointer

    ck = Checkpointer(str(tmp_path))
    assert ck.latest_step() == 6
    train_mod.main(args + ["--resume", "--steps", "8"])
    assert Checkpointer(str(tmp_path)).latest_step() == 8
